//! Design-space exploration — the paper's contribution (Fig. 1).
//!
//! The automated workflow:
//!
//! 1. **Global magnitude pruning as a reference** — stage 1 of the python
//!    compile path exports `prune_profile.json`: per-layer achievable
//!    sparsity vs accuracy; [`crate::config::PruneProfile`] carries it.
//! 2. **Heuristic folding search with secondary relaxation** —
//!    [`heuristic`]: find the cheapest folding that meets a throughput
//!    target, then relax non-bottleneck layers to reclaim resources.
//! 3. **Iterative bottleneck elimination** — [`bottleneck`]: estimate
//!    per-layer latency/LUTs from the graph, and mitigate the latency
//!    bottleneck with *sparse unfolding* (full unroll + engine-free
//!    unstructured pruning) or *factor unfolding* (next legal PE/SIMD
//!    step), whichever is better per LUT, under the device budget; free
//!    wins (sparse-unfold cheaper than current folded form) are applied
//!    immediately. Stops when no legal move improves throughput within
//!    the constraint.
//!
//! [`Strategy`] enumerates the Table-I design points; [`run`] produces the
//! folding configuration + cost estimate for any of them, and
//! `report::DseReport` records the iteration log (the Fig. 1 trace).

pub mod bottleneck;
pub mod heuristic;
pub mod pareto;
pub mod report;

use crate::config::{FoldingConfigFile, PruneProfile};
use crate::cost::{self, ModelCost};
use crate::device::Device;
use crate::folding::{FoldingConfig, LayerFold};
use crate::graph::Graph;
use crate::util::error::{Error, Result};

pub use report::DseReport;

/// The design strategies of Table I (plus the fully folded Fig. 2 row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// PE = SIMD = 1 everywhere (Fig. 2 "fully folded").
    FullyFolded,
    /// FINN-style throughput-target folding, dense (Table I row 3).
    AutoFold,
    /// Auto folding with partial-sparse folded layers (row 4).
    AutoFoldPrune,
    /// Dense full unroll of every MAC layer (row 5).
    Unfold,
    /// Full unroll + engine-free global pruning (row 6).
    UnfoldPrune,
    /// The LogicSparse DSE (row 7).
    Proposed,
}

impl Strategy {
    /// Every strategy, in Table-I order.
    pub const ALL: [Strategy; 6] = [
        Strategy::FullyFolded,
        Strategy::AutoFold,
        Strategy::AutoFoldPrune,
        Strategy::Unfold,
        Strategy::UnfoldPrune,
        Strategy::Proposed,
    ];

    /// Canonical CLI / config-file name.
    pub fn as_str(&self) -> &'static str {
        match self {
            Strategy::FullyFolded => "fully_folded",
            Strategy::AutoFold => "auto_fold",
            Strategy::AutoFoldPrune => "auto_fold_prune",
            Strategy::Unfold => "unfold",
            Strategy::UnfoldPrune => "unfold_prune",
            Strategy::Proposed => "proposed",
        }
    }

    /// Paper Table-I row label.
    pub fn label(&self) -> &'static str {
        match self {
            Strategy::FullyFolded => "Fully folded",
            Strategy::AutoFold => "Auto folding",
            Strategy::AutoFoldPrune => "Auto+Pruning",
            Strategy::Unfold => "Unfold",
            Strategy::UnfoldPrune => "Unfold+Pruning",
            Strategy::Proposed => "Proposed",
        }
    }

    /// Parse a canonical strategy name.
    pub fn parse(s: &str) -> Result<Strategy> {
        Strategy::ALL
            .iter()
            .copied()
            .find(|st| st.as_str() == s)
            .ok_or_else(|| Error::config(format!("unknown strategy '{s}'")))
    }
}

/// DSE tuning knobs.
#[derive(Debug, Clone)]
pub struct DseOptions {
    /// AutoFold throughput target (FPS); `None` picks the paper's balanced
    /// operating point (bottleneck II within 2x of the cheapest balanced
    /// solution).
    pub auto_fold_target_fps: f64,
    /// Fraction of the device LUT budget the accelerator may use.
    pub budget_fraction: f64,
    /// Maximum bottleneck-elimination iterations (safety bound).
    pub max_iterations: usize,
    /// Minimum accuracy the pruning reference must retain before its
    /// sparsities are trusted (rows below this are ignored).
    pub min_reference_accuracy: f64,
}

impl Default for DseOptions {
    fn default() -> Self {
        DseOptions {
            auto_fold_target_fps: 65_000.0,
            budget_fraction: 1.0,
            max_iterations: 64,
            // Rows below 50% accuracy are beyond what re-sparse fine-tuning
            // reliably recovers; the profile's reference point caps the rest.
            min_reference_accuracy: 0.5,
        }
    }
}

/// Outcome of one DSE run.
#[derive(Debug, Clone)]
pub struct DseResult {
    /// The strategy that was explored.
    pub strategy: Strategy,
    /// The chosen per-layer folding.
    pub folding: FoldingConfig,
    /// Cost-model estimate of the chosen configuration.
    pub cost: ModelCost,
    /// Iteration log (the Fig. 1 trace).
    pub report: DseReport,
}

impl DseResult {
    /// Package as the interchange file python stage 2 consumes.
    pub fn to_file(&self, device: &Device) -> FoldingConfigFile {
        FoldingConfigFile {
            device: device.name.to_string(),
            strategy: self.strategy.as_str().to_string(),
            f_mhz: self.cost.f_mhz,
            est_luts: self.cost.total_luts,
            est_throughput_fps: self.cost.throughput_fps,
            est_latency_us: self.cost.latency_s * 1e6,
            folding: self.folding.clone(),
        }
    }
}

/// Per-layer sparsity the pruning reference supports, respecting the
/// accuracy floor.
pub fn reference_sparsities(profile: &PruneProfile, opts: &DseOptions, g: &Graph) -> Vec<(String, f64)> {
    // Use the best (sparsest) row that clears the accuracy floor and does
    // not exceed the calibrated reference operating point; fall back to
    // the reference row if none do (fine-tuning recovers accuracy — the
    // floor guards only against absurd operating points).
    let row = profile
        .rows
        .iter()
        .filter(|r| r.accuracy >= opts.min_reference_accuracy)
        .filter(|r| r.global_sparsity <= profile.reference_global_sparsity + 0.05)
        .max_by(|a, b| a.global_sparsity.partial_cmp(&b.global_sparsity).unwrap())
        .or_else(|| {
            profile.rows.iter().min_by(|a, b| {
                let da = (a.global_sparsity - profile.reference_global_sparsity).abs();
                let db = (b.global_sparsity - profile.reference_global_sparsity).abs();
                da.partial_cmp(&db).unwrap()
            })
        });
    match row {
        Some(r) => g
            .mac_nodes()
            .map(|n| {
                let s = r
                    .layers
                    .iter()
                    .find(|(name, _)| name == &n.name)
                    .map(|(_, s)| *s)
                    .unwrap_or(0.0);
                (n.name.clone(), s.clamp(0.0, 0.97))
            })
            .collect(),
        None => g.mac_nodes().map(|n| (n.name.clone(), 0.0)).collect(),
    }
}

/// Run one strategy end to end: folding decisions + cost estimate.
pub fn run(
    strategy: Strategy,
    g: &Graph,
    dev: &Device,
    profile: &PruneProfile,
    opts: &DseOptions,
) -> Result<DseResult> {
    let mut report = DseReport::new(strategy.as_str());
    let sparsities = reference_sparsities(profile, opts, g);
    let spars_of = |name: &str| -> f64 {
        sparsities
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, s)| *s)
            .unwrap_or(0.0)
    };

    let folding = match strategy {
        Strategy::FullyFolded => FoldingConfig::minimal(g),
        Strategy::Unfold => FoldingConfig::unrolled(g),
        Strategy::UnfoldPrune => {
            let mut cfg = FoldingConfig::unrolled(g);
            for (name, f) in cfg.layers.iter_mut() {
                let node = g.node(name)?;
                *f = LayerFold::unrolled_sparse(node, spars_of(name));
            }
            cfg
        }
        Strategy::AutoFold => {
            heuristic::auto_fold(g, dev, opts, /*allow_sparse=*/ None, &mut report)?
        }
        Strategy::AutoFoldPrune => {
            heuristic::auto_fold(g, dev, opts, Some(&sparsities), &mut report)?
        }
        Strategy::Proposed => {
            // Balanced baseline first (Fig. 1), then iterative bottleneck
            // elimination with sparse/factor unfolding.
            let base = heuristic::auto_fold(g, dev, opts, None, &mut report)?;
            bottleneck::eliminate(g, dev, base, &sparsities, opts, &mut report)?
        }
    };

    folding.check(g)?;
    let cost = cost::evaluate(g, &folding, dev)?;
    report.mark_servable(&folding);
    report.finish(&cost);
    Ok(DseResult { strategy, folding, cost, report })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::XCU50;
    use crate::graph::builder::lenet5;

    fn profile(g: &Graph) -> PruneProfile {
        PruneProfile::uniform(g, &[0.5, 0.7, 0.8], 0.95)
    }

    #[test]
    fn all_strategies_produce_legal_configs() {
        let g = lenet5();
        let p = profile(&g);
        for st in Strategy::ALL {
            let r = run(st, &g, &XCU50, &p, &DseOptions::default()).unwrap();
            r.folding.check(&g).unwrap();
            assert!(r.cost.total_luts > 0);
            assert!(r.cost.throughput_fps > 0.0);
            // Every explored design point is annotated with the kernel
            // form the baked compile pass would serve it as.
            assert_eq!(r.report.servable.len(), r.folding.layers.len());
        }
    }

    #[test]
    fn table1_shape_holds() {
        // The core reproduction claim, at the estimate level:
        //  - Proposed throughput > dense Unfold (paper: 1.23x);
        //  - Proposed LUTs < ~10% of dense Unfold (paper: ~5%);
        //  - UnfoldPrune between them;
        //  - AutoFold far cheaper and far slower.
        let g = lenet5();
        let p = profile(&g);
        let opts = DseOptions::default();
        let unfold = run(Strategy::Unfold, &g, &XCU50, &p, &opts).unwrap().cost;
        let unfold_p = run(Strategy::UnfoldPrune, &g, &XCU50, &p, &opts).unwrap().cost;
        let proposed = run(Strategy::Proposed, &g, &XCU50, &p, &opts).unwrap().cost;
        let auto = run(Strategy::AutoFold, &g, &XCU50, &p, &opts).unwrap().cost;

        assert!(
            proposed.throughput_fps > unfold.throughput_fps * 1.1,
            "proposed {} vs unfold {}",
            proposed.throughput_fps,
            unfold.throughput_fps
        );
        assert!(
            (proposed.total_luts as f64) < unfold.total_luts as f64 * 0.12,
            "proposed {} vs unfold {} LUTs",
            proposed.total_luts,
            unfold.total_luts
        );
        assert!(unfold_p.throughput_fps >= unfold.throughput_fps);
        assert!(unfold_p.total_luts < unfold.total_luts / 2);
        assert!(auto.total_luts < 20_000);
        assert!(auto.throughput_fps < proposed.throughput_fps / 2.0);
    }

    #[test]
    fn strategy_roundtrip() {
        for st in Strategy::ALL {
            assert_eq!(Strategy::parse(st.as_str()).unwrap(), st);
        }
        assert!(Strategy::parse("bogus").is_err());
    }

    #[test]
    fn accuracy_floor_limits_sparsity() {
        let g = lenet5();
        let mut p = PruneProfile::uniform(&g, &[0.5, 0.9], 0.95);
        p.rows[1].accuracy = 0.3; // 0.9-sparsity row is bad
        let opts = DseOptions { min_reference_accuracy: 0.9, ..Default::default() };
        let s = reference_sparsities(&p, &opts, &g);
        assert!(s.iter().all(|(_, v)| (*v - 0.5).abs() < 1e-9), "{s:?}");
    }
}
