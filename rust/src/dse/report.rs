//! DSE iteration log — the textual trace of the Fig. 1 workflow.
//!
//! Every decision (fold step, relaxation, sparse unfold, rejection) is
//! recorded with its estimated effect, so `logicsparse dse --verbose`
//! reproduces the narrative of the paper's Sec. II and EXPERIMENTS.md can
//! quote real traces.

use crate::cost::ModelCost;
use crate::folding::FoldingConfig;

/// One DSE decision.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Heuristic folding raised parallelism on a layer.
    FoldUp { layer: String, pe: usize, simd: usize, ii: u64 },
    /// Secondary relaxation lowered parallelism on a non-bottleneck.
    Relax { layer: String, pe: usize, simd: usize, luts_saved: u64 },
    /// A layer was sparse-unfolded (engine-free full unroll).
    SparseUnfold { layer: String, sparsity: f64, luts_before: u64, luts_after: u64 },
    /// A layer was partially unrolled with sparse packing.
    PartialSparse { layer: String, pe: usize, simd: usize, sparsity: f64 },
    /// Factor unfolding on the bottleneck.
    FactorUnfold { layer: String, pe: usize, simd: usize, ii: u64 },
    /// A candidate move was evaluated and rejected.
    Reject { layer: String, reason: String },
    /// Loop terminated.
    Stop { reason: String },
}

impl Step {
    /// One-line trace rendering of the decision.
    pub fn render(&self) -> String {
        match self {
            Step::FoldUp { layer, pe, simd, ii } => {
                format!("fold-up    {layer}: PE={pe} SIMD={simd} (II -> {ii})")
            }
            Step::Relax { layer, pe, simd, luts_saved } => {
                format!("relax      {layer}: PE={pe} SIMD={simd} (-{luts_saved} LUTs)")
            }
            Step::SparseUnfold { layer, sparsity, luts_before, luts_after } => format!(
                "sparse-unfold {layer}: s={sparsity:.2} ({luts_before} -> {luts_after} LUTs)"
            ),
            Step::PartialSparse { layer, pe, simd, sparsity } => {
                format!("partial-sparse {layer}: PE={pe} SIMD={simd} s={sparsity:.2}")
            }
            Step::FactorUnfold { layer, pe, simd, ii } => {
                format!("factor-unfold {layer}: PE={pe} SIMD={simd} (II -> {ii})")
            }
            Step::Reject { layer, reason } => format!("reject     {layer}: {reason}"),
            Step::Stop { reason } => format!("stop: {reason}"),
        }
    }
}

/// The full trace of one DSE run.
#[derive(Debug, Clone)]
pub struct DseReport {
    /// Strategy the trace belongs to.
    pub strategy: String,
    /// Every recorded decision, in order.
    pub steps: Vec<Step>,
    /// Bottleneck-elimination iterations executed.
    pub iterations: usize,
    /// Which explored design points the baked-kernel compile pass can
    /// serve, per layer: `(layer, style name, served description)` —
    /// set by [`DseReport::mark_servable`].
    pub servable: Vec<(String, String, String)>,
    /// One-line cost summary, set by [`DseReport::finish`].
    pub final_summary: Option<String>,
}

impl DseReport {
    /// An empty trace for `strategy`.
    pub fn new(strategy: &str) -> Self {
        DseReport {
            strategy: strategy.to_string(),
            steps: Vec::new(),
            iterations: 0,
            servable: Vec::new(),
            final_summary: None,
        }
    }

    /// Record one decision.
    pub fn push(&mut self, step: Step) {
        self.steps.push(step);
    }

    /// Count one bottleneck-elimination iteration.
    pub fn next_iteration(&mut self) {
        self.iterations += 1;
    }

    /// Record, for every layer of the chosen folding, how the baked
    /// kernel compile pass would serve it (every [`crate::folding::Style`]
    /// maps to a servable kernel form — see
    /// [`crate::kernel::served_flavour`]). This closes the DSE loop: the
    /// explored design point is annotated with the concrete schedule that
    /// serving would execute, not just a cost estimate.
    pub fn mark_servable(&mut self, folding: &FoldingConfig) {
        self.servable = folding
            .layers
            .iter()
            .map(|(name, fold)| {
                (
                    name.clone(),
                    fold.style.as_str().to_string(),
                    crate::kernel::served_flavour(fold.style).to_string(),
                )
            })
            .collect();
    }

    /// Record the final cost summary line.
    pub fn finish(&mut self, cost: &ModelCost) {
        self.final_summary = Some(format!(
            "{}: {} LUTs, f={:.1} MHz, II={} cyc, {:.0} FPS, {:.2} us",
            self.strategy,
            cost.total_luts,
            cost.f_mhz,
            cost.max_ii,
            cost.throughput_fps,
            cost.latency_s * 1e6
        ));
    }

    /// Render the full trace, one line per decision.
    pub fn render(&self) -> String {
        let mut out = format!("DSE trace [{}] ({} iterations)\n", self.strategy, self.iterations);
        for s in &self.steps {
            out.push_str("  ");
            out.push_str(&s.render());
            out.push('\n');
        }
        if !self.servable.is_empty() {
            out.push_str("servable as:\n");
            for (layer, style, served) in &self.servable {
                out.push_str(&format!("  {layer:<12} {style:<16} -> {served}\n"));
            }
        }
        if let Some(sum) = &self.final_summary {
            out.push_str(sum);
            out.push('\n');
        }
        out
    }

    /// Count of applied (non-reject) optimisation moves.
    pub fn moves(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| !matches!(s, Step::Reject { .. } | Step::Stop { .. }))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_steps() {
        let mut r = DseReport::new("proposed");
        r.push(Step::SparseUnfold {
            layer: "conv1".into(),
            sparsity: 0.6,
            luts_before: 2000,
            luts_after: 800,
        });
        r.push(Step::Stop { reason: "II floor reached".into() });
        let text = r.render();
        assert!(text.contains("sparse-unfold conv1"));
        assert!(text.contains("II floor"));
        assert_eq!(r.moves(), 1);
    }

    #[test]
    fn servable_section_names_every_layer() {
        use crate::folding::FoldingConfig;
        use crate::graph::builder::lenet5;

        let g = lenet5();
        let mut r = DseReport::new("proposed");
        assert!(r.servable.is_empty());
        r.mark_servable(&FoldingConfig::unrolled(&g));
        assert_eq!(r.servable.len(), 5);
        let text = r.render();
        assert!(text.contains("servable as:"));
        for (layer, style, served) in &r.servable {
            assert!(text.contains(layer.as_str()), "{layer} missing");
            assert_eq!(style, "unrolled_dense");
            assert_eq!(served, "dense kernel");
        }
    }
}
