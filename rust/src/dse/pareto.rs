//! Pareto-frontier utilities (LUTs vs throughput).
//!
//! The paper claims the proposed scheme "advances the design's Pareto
//! frontier"; the ablation bench sweeps budgets/targets through the DSE
//! and uses this module to extract and compare frontiers.

/// One evaluated design point.
#[derive(Debug, Clone, PartialEq)]
pub struct Point {
    /// Human-readable label of the design point.
    pub label: String,
    /// Estimated LUT usage.
    pub luts: u64,
    /// Estimated throughput.
    pub throughput_fps: f64,
}

impl Point {
    /// Does `self` dominate `other` (no worse in both, better in one)?
    pub fn dominates(&self, other: &Point) -> bool {
        let no_worse = self.luts <= other.luts && self.throughput_fps >= other.throughput_fps;
        let better = self.luts < other.luts || self.throughput_fps > other.throughput_fps;
        no_worse && better
    }
}

/// Extract the Pareto-optimal subset, sorted by LUTs ascending.
pub fn frontier(points: &[Point]) -> Vec<Point> {
    let mut front: Vec<Point> = points
        .iter()
        .filter(|p| !points.iter().any(|q| q.dominates(p)))
        .cloned()
        .collect();
    front.sort_by(|a, b| a.luts.cmp(&b.luts).then(b.throughput_fps.total_cmp(&a.throughput_fps)));
    front.dedup_by(|a, b| a.luts == b.luts && a.throughput_fps == b.throughput_fps);
    front
}

/// Hypervolume indicator against a reference corner (bigger is better):
/// the area dominated by the frontier within [0, ref_luts] x [0, ref_fps].
pub fn hypervolume(front: &[Point], ref_luts: u64, _ref_fps: f64) -> f64 {
    // Sweep LUTs left->right; each frontier point contributes a rectangle
    // from its LUTs to the next point's LUTs at its throughput.
    let mut pts: Vec<&Point> = front.iter().filter(|p| p.luts <= ref_luts).collect();
    pts.sort_by_key(|p| p.luts);
    let mut hv = 0.0;
    for (i, p) in pts.iter().enumerate() {
        let next_luts = pts.get(i + 1).map(|q| q.luts).unwrap_or(ref_luts).min(ref_luts);
        let width = (next_luts.saturating_sub(p.luts)) as f64;
        hv += width * p.throughput_fps;
    }
    hv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::check;

    fn p(label: &str, luts: u64, fps: f64) -> Point {
        Point { label: label.into(), luts, throughput_fps: fps }
    }

    #[test]
    fn dominance() {
        assert!(p("a", 100, 10.0).dominates(&p("b", 200, 5.0)));
        assert!(p("a", 100, 10.0).dominates(&p("b", 100, 5.0)));
        assert!(!p("a", 100, 10.0).dominates(&p("b", 50, 20.0)));
        assert!(!p("a", 100, 10.0).dominates(&p("a2", 100, 10.0)));
    }

    #[test]
    fn frontier_filters_dominated() {
        let pts = vec![
            p("cheap-slow", 10, 1.0),
            p("dominated", 50, 0.5),
            p("mid", 50, 5.0),
            p("fast", 500, 50.0),
            p("bad", 600, 40.0),
        ];
        let f = frontier(&pts);
        let labels: Vec<_> = f.iter().map(|q| q.label.as_str()).collect();
        assert_eq!(labels, vec!["cheap-slow", "mid", "fast"]);
    }

    #[test]
    fn prop_frontier_mutually_nondominated() {
        check("frontier points don't dominate each other", 100, |g| {
            let pts: Vec<Point> = (0..g.usize(1, 30))
                .map(|i| p(&format!("p{i}"), g.usize(1, 1000) as u64, g.f64(0.1, 100.0)))
                .collect();
            let f = frontier(&pts);
            for a in &f {
                for b in &f {
                    assert!(!a.dominates(b), "{a:?} dominates {b:?}");
                }
            }
            // Every input point is dominated-by-or-on the frontier.
            for q in &pts {
                assert!(
                    f.iter().any(|a| a == q || a.dominates(q)),
                    "{q:?} neither on nor dominated by frontier"
                );
            }
        });
    }

    #[test]
    fn hypervolume_grows_with_better_points() {
        let f1 = frontier(&[p("a", 100, 10.0)]);
        let f2 = frontier(&[p("a", 100, 10.0), p("b", 200, 30.0)]);
        let h1 = hypervolume(&f1, 1000, 100.0);
        let h2 = hypervolume(&f2, 1000, 100.0);
        assert!(h2 > h1);
    }
}
