//! Offline stand-in for the `xla` crate (PJRT bindings over
//! xla_extension). crates.io and libxla are unreachable in the build
//! environment, so this crate provides the exact type surface
//! `runtime::ModelRuntime` consumes — HLO-text loading and literal
//! plumbing work, but creating a PJRT client fails with an actionable
//! error. Everything downstream of the serving coordinator that does not
//! need real XLA (the sharded execution plane, the synthetic backend, the
//! cycle simulator) runs unaffected; artifact-backed paths skip or report
//! the stub error.
//!
//! Swapping the real crate back in is a one-line change in the root
//! Cargo.toml (`xla = "..."` instead of the path dependency).

use std::fmt;

/// XLA error (stub): a message string.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const STUB_MSG: &str = "PJRT unavailable: built against the offline xla stub \
(rust/vendor/xla). Artifact-backed serving needs the real `xla` crate + \
libxla; use the synthetic engine backend or the cycle simulator instead.";

mod private {
    /// Element types the stub can hold (only f32 is exercised here).
    pub trait Sealed {}
    impl Sealed for f32 {}
}

/// Native element type marker for [`Literal::to_vec`].
pub trait NativeType: private::Sealed + Sized {
    fn from_f32(v: f32) -> Self;
}
impl NativeType for f32 {
    fn from_f32(v: f32) -> Self {
        v
    }
}

/// A host-side tensor literal (stub: f32 payload + dims).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    /// Reshape without changing the payload.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} ({} elements) does not fit payload of {}",
                dims,
                n,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Unwrap a 1-tuple result (stub literals are never tuples; identity).
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }

    /// Copy out the payload as the requested native type.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (stub: retains the text).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file. Parsing is not attempted — the stub only
    /// verifies the file is readable so missing-artifact errors still
    /// surface at the right layer.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("{path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation (stub wrapper over the proto).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    pub proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: proto.clone() }
    }
}

/// Device-side buffer handle (stub: host literal).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable (stub: uninstantiable — compiling requires a
/// client, and client construction fails).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Matches the real signature shape used by the runtime:
    /// `execute::<Literal>(&[lit])?[0][0].to_literal_sync()?`.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(STUB_MSG.to_string()))
    }
}

/// PJRT client (stub: construction always fails with [`STUB_MSG`]).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error(STUB_MSG.to_string()))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(STUB_MSG.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn client_fails_actionably() {
        let err = match PjRtClient::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub client must not construct"),
        };
        assert!(err.to_string().contains("stub"));
    }
}
