//! Offline stand-in for the `byteorder` crate (crates.io is unreachable in
//! the build environment). API-compatible with the subset `util::lstw`
//! uses: `ReadBytesExt` / `WriteBytesExt` parameterised by `LittleEndian`.
//!
//! `BigEndian` is provided for completeness; the LSTW format is LE-only.

use std::io::{self, Read, Write};

/// Byte-order marker trait: converts between integers and byte arrays.
pub trait ByteOrder {
    fn read_u16(buf: [u8; 2]) -> u16;
    fn read_u32(buf: [u8; 4]) -> u32;
    fn read_u64(buf: [u8; 8]) -> u64;
    fn write_u16(v: u16) -> [u8; 2];
    fn write_u32(v: u32) -> [u8; 4];
    fn write_u64(v: u64) -> [u8; 8];
}

/// Little-endian byte order (the LSTW interchange order).
pub enum LittleEndian {}

impl ByteOrder for LittleEndian {
    fn read_u16(buf: [u8; 2]) -> u16 {
        u16::from_le_bytes(buf)
    }
    fn read_u32(buf: [u8; 4]) -> u32 {
        u32::from_le_bytes(buf)
    }
    fn read_u64(buf: [u8; 8]) -> u64 {
        u64::from_le_bytes(buf)
    }
    fn write_u16(v: u16) -> [u8; 2] {
        v.to_le_bytes()
    }
    fn write_u32(v: u32) -> [u8; 4] {
        v.to_le_bytes()
    }
    fn write_u64(v: u64) -> [u8; 8] {
        v.to_le_bytes()
    }
}

/// Big-endian byte order.
pub enum BigEndian {}

impl ByteOrder for BigEndian {
    fn read_u16(buf: [u8; 2]) -> u16 {
        u16::from_be_bytes(buf)
    }
    fn read_u32(buf: [u8; 4]) -> u32 {
        u32::from_be_bytes(buf)
    }
    fn read_u64(buf: [u8; 8]) -> u64 {
        u64::from_be_bytes(buf)
    }
    fn write_u16(v: u16) -> [u8; 2] {
        v.to_be_bytes()
    }
    fn write_u32(v: u32) -> [u8; 4] {
        v.to_be_bytes()
    }
    fn write_u64(v: u64) -> [u8; 8] {
        v.to_be_bytes()
    }
}

/// `Read` extension: typed little/big-endian reads.
pub trait ReadBytesExt: Read {
    fn read_u8(&mut self) -> io::Result<u8> {
        let mut b = [0u8; 1];
        self.read_exact(&mut b)?;
        Ok(b[0])
    }

    fn read_i8(&mut self) -> io::Result<i8> {
        Ok(self.read_u8()? as i8)
    }

    fn read_u16<B: ByteOrder>(&mut self) -> io::Result<u16> {
        let mut b = [0u8; 2];
        self.read_exact(&mut b)?;
        Ok(B::read_u16(b))
    }

    fn read_u32<B: ByteOrder>(&mut self) -> io::Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(B::read_u32(b))
    }

    fn read_u64<B: ByteOrder>(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b)?;
        Ok(B::read_u64(b))
    }

    fn read_i32<B: ByteOrder>(&mut self) -> io::Result<i32> {
        Ok(self.read_u32::<B>()? as i32)
    }

    fn read_f32<B: ByteOrder>(&mut self) -> io::Result<f32> {
        Ok(f32::from_bits(self.read_u32::<B>()?))
    }
}

impl<R: Read + ?Sized> ReadBytesExt for R {}

/// `Write` extension: typed little/big-endian writes.
pub trait WriteBytesExt: Write {
    fn write_u8(&mut self, v: u8) -> io::Result<()> {
        self.write_all(&[v])
    }

    fn write_i8(&mut self, v: i8) -> io::Result<()> {
        self.write_all(&[v as u8])
    }

    fn write_u16<B: ByteOrder>(&mut self, v: u16) -> io::Result<()> {
        self.write_all(&B::write_u16(v))
    }

    fn write_u32<B: ByteOrder>(&mut self, v: u32) -> io::Result<()> {
        self.write_all(&B::write_u32(v))
    }

    fn write_u64<B: ByteOrder>(&mut self, v: u64) -> io::Result<()> {
        self.write_all(&B::write_u64(v))
    }

    fn write_i32<B: ByteOrder>(&mut self, v: i32) -> io::Result<()> {
        self.write_u32::<B>(v as u32)
    }

    fn write_f32<B: ByteOrder>(&mut self, v: f32) -> io::Result<()> {
        self.write_u32::<B>(v.to_bits())
    }
}

impl<W: Write + ?Sized> WriteBytesExt for W {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_le() {
        let mut buf = Vec::new();
        buf.write_u16::<LittleEndian>(0xBEEF).unwrap();
        buf.write_u32::<LittleEndian>(0xDEAD_BEEF).unwrap();
        buf.write_u64::<LittleEndian>(0x0123_4567_89AB_CDEF).unwrap();
        buf.write_f32::<LittleEndian>(-1.5).unwrap();
        buf.write_i32::<LittleEndian>(-42).unwrap();
        buf.write_u8(7).unwrap();
        buf.write_i8(-7).unwrap();

        let mut r = &buf[..];
        assert_eq!(r.read_u16::<LittleEndian>().unwrap(), 0xBEEF);
        assert_eq!(r.read_u32::<LittleEndian>().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.read_u64::<LittleEndian>().unwrap(), 0x0123_4567_89AB_CDEF);
        assert_eq!(r.read_f32::<LittleEndian>().unwrap(), -1.5);
        assert_eq!(r.read_i32::<LittleEndian>().unwrap(), -42);
        assert_eq!(r.read_u8().unwrap(), 7);
        assert_eq!(r.read_i8().unwrap(), -7);
        assert!(r.is_empty());
    }

    #[test]
    fn le_byte_layout_matches_spec() {
        let mut buf = Vec::new();
        buf.write_u32::<LittleEndian>(1).unwrap();
        assert_eq!(buf, vec![1, 0, 0, 0]);
        let mut be = Vec::new();
        be.write_u32::<BigEndian>(1).unwrap();
        assert_eq!(be, vec![0, 0, 0, 1]);
    }

    #[test]
    fn short_reads_error() {
        let mut r: &[u8] = &[1, 2];
        assert!(r.read_u32::<LittleEndian>().is_err());
    }
}
