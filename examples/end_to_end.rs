//! END-TO-END DRIVER (DESIGN.md §6 E6): the full LogicSparse system on a
//! real workload, proving all layers compose.
//!
//!  1. load the python-exported ONNX-like graph + pruning reference;
//!  2. run the DSE for every Table-I strategy (L3 contribution);
//!  3. *measure* latency/throughput in the cycle-level dataflow simulator
//!     and print Table I + Fig. 2 against the paper's numbers;
//!  4. load the AOT artifacts (Pallas kernels -> HLO, L1+L2) and serve the
//!     entire exported test set through the batching coordinator,
//!     reporting accuracy and wallclock serving throughput;
//!  5. verify the headline claims (51.6x compression / 1.23x throughput /
//!     ~5% LUTs) from measured masks and measured rows.
//!
//! Requires `make artifacts`. The run is recorded in EXPERIMENTS.md.

use logicsparse::config::PruneProfile;
use logicsparse::coordinator::{BatchPolicy, Server, ServerOptions};
use logicsparse::device::XCU50;
use logicsparse::experiments::{fig2, headline, table1, Accuracies};
use logicsparse::graph::import;
use logicsparse::runtime::IMG;
use logicsparse::util::lstw::Store;
use std::time::Duration;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---- 1. compile-path exports ----
    let g = import::load("artifacts/graph.json")?;
    let profile = PruneProfile::load("artifacts/prune_profile.json")?;
    let acc = Accuracies::load("artifacts")?;
    println!(
        "[1] graph '{}' loaded: {} weights; dense accuracy {}%",
        g.model,
        g.total_weights(),
        Accuracies::fmt(acc.dense)
    );

    // ---- 2+3. DSE + simulator: Table I and Fig. 2 ----
    println!("\n[2] running DSE + cycle-level simulation for all strategies…\n");
    let rows = table1::measure(&g, &XCU50, &profile, &acc, 300)?;
    println!("{}", table1::render(&rows));
    for v in table1::shape_checks(&rows) {
        println!("{v}");
    }
    println!();
    let series = fig2::measure(&g, &XCU50, &profile)?;
    println!("{}", fig2::render(&series));
    for v in fig2::shape_checks(&series) {
        println!("{v}");
    }

    // ---- 4. serve the test set through the coordinator ----
    println!("\n[4] serving the exported test set through the coordinator…");
    let ts = Store::read_file("artifacts/testset.lstw")?;
    let images = ts.req("images")?.data.as_f32()?.to_vec();
    let labels = ts.req("labels")?.data.as_i32()?.to_vec();
    let px = IMG * IMG;
    let n = labels.len();

    let server = Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(2) },
        engines: 1,
        ..ServerOptions::artifacts("artifacts", "proposed")
    })?;
    let t0 = std::time::Instant::now();
    let mut correct = 0usize;
    let mut pending = Vec::with_capacity(256);
    for j in 0..n {
        pending.push((server.submit(images[j * px..(j + 1) * px].to_vec())?, labels[j]));
        if pending.len() == 256 {
            for (rx, label) in pending.drain(..) {
                correct += (rx.recv()?.class() == label as usize) as usize;
            }
        }
    }
    for (rx, label) in pending.drain(..) {
        correct += (rx.recv()?.class() == label as usize) as usize;
    }
    let wall = t0.elapsed().as_secs_f64();
    let snap = server.shutdown();
    let served_acc = 100.0 * correct as f64 / n as f64;
    println!("    {}", snap.render());
    println!(
        "    served accuracy {served_acc:.2}% over {n} images | {:.0} img/s wallclock",
        n as f64 / wall
    );

    // ---- 5. headline claims ----
    println!("\n[5] headline verification");
    let h = headline::measure(&rows, "artifacts")?;
    println!("{}", headline::render(&h));

    // Cross-layer consistency: the accuracy served by the rust runtime
    // must match what python measured at export time.
    if let Some(pa) = acc.proposed {
        let diff = (served_acc - pa * 100.0).abs();
        println!(
            "cross-layer accuracy check: python {:.2}% vs served {served_acc:.2}% (|Δ| = {diff:.2} pts) {}",
            pa * 100.0,
            if diff < 0.5 { "PASS" } else { "FAIL" }
        );
    }
    Ok(())
}
