//! Quickstart: load the AOT-compiled LogicSparse accelerator model and
//! classify a few test digits — the smallest possible end-to-end use of
//! the public API.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```

use logicsparse::runtime::{argmax_classes, ModelRuntime, IMG};
use logicsparse::util::lstw::Store;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Load the engine-free sparse model variants compiled by
    //    `make artifacts` (python never runs from here on).
    let rt = ModelRuntime::load("artifacts", "proposed")?;
    println!(
        "loaded '{}' on {} with batch variants {:?}",
        rt.tag,
        rt.platform(),
        rt.batch_sizes()
    );

    // 2. Load the exported test set.
    let ts = Store::read_file("artifacts/testset.lstw")?;
    let images = ts.req("images")?.data.as_f32()?.to_vec();
    let labels = ts.req("labels")?.data.as_i32()?.to_vec();
    let px = IMG * IMG;

    // 3. Classify ten digits through the PJRT executable.
    let n = 10.min(labels.len());
    let logits = rt.infer_padded(&images[..n * px], n)?;
    let classes = argmax_classes(&logits);
    let mut correct = 0;
    for i in 0..n {
        let ok = classes[i] == labels[i] as usize;
        correct += ok as usize;
        println!(
            "  digit {i}: predicted {} | label {} {}",
            classes[i],
            labels[i],
            if ok { "✓" } else { "✗" }
        );
    }
    println!("{correct}/{n} correct");
    Ok(())
}
