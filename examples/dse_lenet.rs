//! The Fig. 1 workflow in one example: run the LogicSparse DSE on
//! LeNet-5 for the XCU50 and print the full decision trace — global
//! pruning reference, heuristic folding with secondary relaxation, then
//! iterative bottleneck elimination with sparse/factor unfolding.
//!
//! Works with or without `make artifacts` (falls back to the built-in
//! graph and a uniform pruning profile).

use logicsparse::config::PruneProfile;
use logicsparse::device::XCU50;
use logicsparse::dse::{self, DseOptions, Strategy};
use logicsparse::folding::space;
use logicsparse::graph::builder::lenet5;
use logicsparse::graph::import;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let g = if std::path::Path::new("artifacts/graph.json").exists() {
        import::load("artifacts/graph.json")?
    } else {
        lenet5()
    };
    let profile = if std::path::Path::new("artifacts/prune_profile.json").exists() {
        PruneProfile::load("artifacts/prune_profile.json")?
    } else {
        PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95)
    };

    let nodes: Vec<_> = g.mac_nodes().collect();
    println!(
        "model {}: {} MAC layers, {} weights, {} MACs/frame",
        g.model,
        nodes.len(),
        g.total_weights(),
        g.total_macs_per_frame()
    );
    println!(
        "joint folding space: {:.2e} points (why the search is heuristic)\n",
        space::joint_space_size(&nodes) as f64
    );

    // Run the paper's strategies and contrast their estimates.
    for st in [Strategy::AutoFold, Strategy::Unfold, Strategy::Proposed] {
        let r = dse::run(st, &g, &XCU50, &profile, &DseOptions::default())?;
        println!("=== {} ===", st.label());
        if st == Strategy::Proposed {
            println!("{}", r.report.render());
        } else if let Some(s) = &r.report.final_summary {
            println!("{s}");
        }
        for (name, f) in &r.folding.layers {
            println!(
                "  {name:<8} {:<16} PE={:<4} SIMD={:<4} sparsity={:.2}",
                f.style.as_str(),
                f.pe,
                f.simd,
                f.sparsity
            );
        }
        println!(
            "  => {} LUTs | f={:.1} MHz | {:.0} FPS | {:.2} us\n",
            r.cost.total_luts,
            r.cost.f_mhz,
            r.cost.throughput_fps,
            r.cost.latency_s * 1e6
        );
    }
    Ok(())
}
