//! Serving example: run the coordinator against the AOT artifacts with an
//! open-loop client (bursty arrivals), comparing two batching policies —
//! the classic latency/throughput trade-off of dynamic batching.
//!
//! Requires `make artifacts`.

use logicsparse::coordinator::{BatchPolicy, Server, ServerOptions};
use logicsparse::runtime::IMG;
use logicsparse::util::lstw::Store;
use logicsparse::util::rng::Pcg32;
use std::time::Duration;

fn run_policy(name: &str, policy: BatchPolicy, images: &[f32], labels: &[i32]) -> Result<(), Box<dyn std::error::Error>> {
    let px = IMG * IMG;
    let n_avail = labels.len();
    let server = Server::start(ServerOptions {
        policy,
        engines: 1,
        artifacts_dir: "artifacts".into(),
        tag: "proposed".into(),
    })?;

    // Open-loop bursty client: bursts of 8..48 requests with small gaps.
    let mut rng = Pcg32::seeded(42);
    let mut pending = Vec::new();
    let mut correct = 0usize;
    let total = 768usize;
    let mut sent = 0usize;
    while sent < total {
        let burst = rng.range(8, 48).min(total - sent);
        for _ in 0..burst {
            let j = sent % n_avail;
            pending.push((server.submit(images[j * px..(j + 1) * px].to_vec())?, labels[j]));
            sent += 1;
        }
        std::thread::sleep(Duration::from_millis(rng.range(0, 4) as u64));
        if pending.len() > 512 {
            for (rx, label) in pending.drain(..) {
                correct += (rx.recv()?.class() == label as usize) as usize;
            }
        }
    }
    for (rx, label) in pending.drain(..) {
        correct += (rx.recv()?.class() == label as usize) as usize;
    }
    let snap = server.shutdown();
    println!("[{name}] {}", snap.render());
    println!(
        "[{name}] accuracy {:.2}% ({total} bursty requests)\n",
        100.0 * correct as f64 / total as f64
    );
    Ok(())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let ts = Store::read_file("artifacts/testset.lstw")?;
    let images = ts.req("images")?.data.as_f32()?.to_vec();
    let labels = ts.req("labels")?.data.as_i32()?.to_vec();

    run_policy("low-latency ", BatchPolicy::low_latency(), &images, &labels)?;
    run_policy("high-thrpt  ", BatchPolicy::high_throughput(), &images, &labels)?;
    Ok(())
}
