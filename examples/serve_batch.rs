//! Serving example: drive the sharded coordinator with an open-loop
//! bursty client from the shared traffic model, comparing two batching
//! policies — the classic latency/throughput trade-off of dynamic
//! batching.
//!
//! With `make artifacts` done, requests run through the PJRT engines and
//! accuracy is checked against the exported labels; without artifacts the
//! example falls back to the synthetic backend so the serving plane is
//! still demonstrated end-to-end.

use logicsparse::coordinator::{
    loadgen, BatchPolicy, Server, ServerOptions, ShedMode,
};
use logicsparse::runtime::{SyntheticRuntime, IMG};
use logicsparse::traffic::Traffic;
use logicsparse::util::lstw::Store;
use std::time::Duration;

struct Dataset {
    images: Vec<f32>,
    /// Expected class per image (exported labels, or the synthetic rule).
    labels: Vec<i32>,
    opts: ServerOptions,
}

fn load_dataset() -> Dataset {
    if let Ok(ts) = Store::read_file("artifacts/testset.lstw") {
        let images = ts.req("images").unwrap().data.as_f32().unwrap().to_vec();
        let labels = ts.req("labels").unwrap().data.as_i32().unwrap().to_vec();
        return Dataset {
            images,
            labels,
            opts: ServerOptions::artifacts("artifacts", "proposed"),
        };
    }
    println!("note: artifacts missing — serving the synthetic backend instead\n");
    let (images, labels) = SyntheticRuntime::dataset(512);
    Dataset {
        images,
        labels,
        opts: ServerOptions::synthetic(Duration::from_micros(100)),
    }
}

fn run_policy(name: &str, policy: BatchPolicy, ds: &Dataset) -> Result<(), Box<dyn std::error::Error>> {
    let px = IMG * IMG;
    let n_avail = ds.labels.len();
    let server = Server::start(ServerOptions { policy, ..ds.opts.clone() })?;

    // Open-loop bursty client: bursts of 32 requests, ~2 ms mean gaps,
    // the same Burst shape the cycle simulator accepts.
    let total = 768u64;
    let traffic = Traffic::bursty(total, 32, 2e-3, 42);
    let rep = loadgen::run_open_loop(
        &server,
        &traffic,
        |i| {
            let j = (i as usize) % n_avail;
            ds.images[j * px..(j + 1) * px].to_vec()
        },
        ShedMode::Retry,
    );
    let snap = server.shutdown();
    println!("[{name}] {}", rep.render());
    println!("[{name}] {}", snap.render());
    assert_eq!(rep.lost, 0, "graceful shutdown dropped responses");

    // Accuracy over a blocking replay of the first images (the open-loop
    // pass above measures throughput; this one checks correctness).
    let check = 96.min(n_avail);
    let server = Server::start(ds.opts.clone())?;
    let mut correct = 0usize;
    for j in 0..check {
        let resp = server.infer_blocking(ds.images[j * px..(j + 1) * px].to_vec())?;
        correct += (resp.class() == ds.labels[j] as usize) as usize;
    }
    let _ = server.shutdown();
    println!(
        "[{name}] accuracy {:.2}% ({check} blocking requests)\n",
        100.0 * correct as f64 / check as f64
    );
    Ok(())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let ds = load_dataset();
    run_policy("low-latency ", BatchPolicy::low_latency(), &ds)?;
    run_policy("high-thrpt  ", BatchPolicy::high_throughput(), &ds)?;
    Ok(())
}
