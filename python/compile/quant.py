"""Uniform affine quantisation primitives (build-time, L2).

The paper targets FINN-style QNNs: low-bit weights and activations whose
values are *baked into logic* after the DSE decides the layer style. Here we
model the same arithmetic in JAX:

- weights: symmetric signed uniform quantisation, per-output-channel scales
  (int4 by default — the LogicSparse LeNet-5 operating point);
- activations: unsigned affine quantisation after ReLU (uint4 by default);
- training uses the straight-through estimator (STE) so QAT gradients flow
  through the rounding.

All functions are pure and shape-polymorphic; they are shared by the
training path (`train.py`), the reference oracle (`kernels/ref.py`) and the
exported inference model (`model.py`), so the numbers that reach the rust
runtime are exactly the numbers the tests check.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

# Default LogicSparse operating point (see DESIGN.md §7): W4A4.
DEFAULT_WEIGHT_BITS = 4
DEFAULT_ACT_BITS = 4


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static quantisation configuration for one layer."""

    weight_bits: int = DEFAULT_WEIGHT_BITS
    act_bits: int = DEFAULT_ACT_BITS
    per_channel: bool = True

    def weight_levels(self) -> int:
        """Number of representable magnitudes on each side of zero."""
        return 2 ** (self.weight_bits - 1) - 1

    def act_levels(self) -> int:
        return 2**self.act_bits - 1


def _ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """round() with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def weight_scale(w: jnp.ndarray, bits: int, per_channel: bool = True) -> jnp.ndarray:
    """Symmetric scale so that max|w| maps to the largest level.

    For per-channel mode the leading axis is treated as the output channel
    (FINN convention: one threshold/scale block per PE lane).
    """
    qmax = 2 ** (bits - 1) - 1
    if per_channel:
        reduce_axes = tuple(range(1, w.ndim))
        amax = jnp.max(jnp.abs(w), axis=reduce_axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w))
    # Guard fully-pruned channels: scale 0 would produce NaNs.
    amax = jnp.maximum(amax, 1e-8)
    return amax / qmax


def fake_quant_weight(
    w: jnp.ndarray, bits: int = DEFAULT_WEIGHT_BITS, per_channel: bool = True
) -> jnp.ndarray:
    """Symmetric fake quantisation with STE; output lies on the int grid."""
    scale = weight_scale(w, bits, per_channel)
    qmax = 2 ** (bits - 1) - 1
    q = _ste_round(w / scale)
    q = jnp.clip(q, -qmax, qmax)
    return q * scale


def quantize_weight_int(
    w: jnp.ndarray, bits: int = DEFAULT_WEIGHT_BITS, per_channel: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Integer codes + scale (the pair the FPGA flow would bake into LUTs)."""
    scale = weight_scale(w, bits, per_channel)
    qmax = 2 ** (bits - 1) - 1
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize_weight(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def fake_quant_act(
    x: jnp.ndarray, bits: int = DEFAULT_ACT_BITS, ceil: float = 6.0
) -> jnp.ndarray:
    """Unsigned fake quantisation for post-ReLU activations.

    A fixed clipping ceiling (ReLU6-style) keeps the scale static, which is
    what a dataflow accelerator does: thresholds are compiled in, not
    computed at run time.
    """
    qmax = 2**bits - 1
    scale = ceil / qmax
    x = jnp.clip(x, 0.0, ceil)
    q = _ste_round(x / scale)
    return q * scale


def quant_error(w: jnp.ndarray, bits: int, per_channel: bool = True) -> jnp.ndarray:
    """Mean-squared fake-quantisation error (used by tests/diagnostics)."""
    return jnp.mean((w - fake_quant_weight(w, bits, per_channel)) ** 2)


def model_bits_dense(n_weights: int, bits_fp: int = 32) -> int:
    """Bit cost of the uncompressed fp32 model (compression-ratio numerator)."""
    return n_weights * bits_fp


def model_bits_engine_free(nnz: int, weight_bits: int) -> int:
    """Bit cost of the engine-free sparse model: only surviving weights,
    *no index storage* — positions are baked into logic (the paper's point:
    unstructured sparsity without CSR/bitmap overhead)."""
    return nnz * weight_bits
