"""L1: engine-free unstructured-sparse matmul (the paper's core mechanism).

FPGA story: a fully-unrolled layer bakes each non-zero weight into LUTs;
pruned weights synthesise to *nothing* — no sparse engine, no index decode,
no scheduler. TPU/Pallas re-think (DESIGN.md §3): all sparsity bookkeeping is
resolved at **trace time**:

  1. `pack_sparse_blocks` partitions the IN axis into SIMD-like blocks and
     drops blocks whose mask is entirely zero (build time, numpy);
  2. the surviving block indices become *static* slices of the activation —
     in the lowered HLO they are constant-offset `slice` ops (wiring, not
     computation), exactly like FPGA routing;
  3. a single dense Pallas matmul runs over the packed weights.

The run-time executable therefore contains no mask tensor, no gather, no
CSR walk: it is a smaller dense matmul plus static wiring — engine-free.
The denser the pruning, the fewer MXU passes and the smaller the VMEM
footprint (the TPU analogue of "fewer LUTs, shallower adder tree").
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from . import matmul as mm
from . import ref

DEFAULT_BLOCK = 16  # SIMD-block granularity of zero-block elision.


def plan_sparse_matmul(
    w_t: np.ndarray, mask: np.ndarray, block: int = DEFAULT_BLOCK
) -> dict:
    """Build-time plan: packed weights + static live-block index list.

    Returns a dict (kept JSON-friendly for export into DESIGN/EXPERIMENTS
    perf notes): packed [L*block, OUT] f32, live indices, elision stats.
    """
    packed, live = ref.pack_sparse_blocks(w_t, mask, block)
    n_blocks = (w_t.shape[0] + block - 1) // block
    return {
        "packed": packed,
        "live": live,
        "block": block,
        "in_dim": int(w_t.shape[0]),
        "out_dim": int(w_t.shape[1]),
        "n_blocks_total": int(n_blocks),
        "n_blocks_live": len(live),
        "elision_ratio": 1.0 - len(live) / max(1, n_blocks),
    }


def gather_live_blocks(
    x: jnp.ndarray, live: Sequence[int], block: int, in_dim: int
) -> jnp.ndarray:
    """Static re-wiring of activations: concat of the surviving IN blocks.

    All offsets are python ints at trace time, so the lowered HLO contains
    only constant slices + one concat — no runtime index arithmetic.
    """
    xp = x
    pad = (-in_dim) % block
    if pad:
        xp = jnp.pad(x, ((0, 0), (0, pad)))
    parts = [xp[:, i * block : (i + 1) * block] for i in live]
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def sparse_matmul(
    x: jnp.ndarray,
    plan: dict,
    *,
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool = mm.INTERPRET,
) -> jnp.ndarray:
    """y = x @ (w_t * mask) with compile-time-specialised sparsity.

    x:[B, IN] -> [B, OUT]; `plan` comes from `plan_sparse_matmul`. Tiles
    default to `mm.auto_tiles` over the PACKED inner dim — the engine-free
    win shows up here as fewer k-steps after elision.
    """
    assert x.shape[1] == plan["in_dim"], (x.shape, plan["in_dim"])
    xg = gather_live_blocks(x, plan["live"], plan["block"], plan["in_dim"])
    packed = jnp.asarray(plan["packed"])
    return mm.matmul(xg, packed, bm=bm, bk=bk, bn=bn, interpret=interpret)


def sparse_matmul_dense_fallback(
    x: jnp.ndarray, w_t: jnp.ndarray, mask: jnp.ndarray, **kw
) -> jnp.ndarray:
    """Masked-dense path (used for folded layers and as a differential test
    partner for the packed path)."""
    return mm.matmul(x, jnp.asarray(w_t) * jnp.asarray(mask), **kw)


def perf_estimate(plan: dict, batch: int, bm: int = mm.DEF_BM,
                  bk: int = mm.DEF_BK, bn: int = mm.DEF_BN) -> dict:
    """Static perf model of the engine-free kernel vs its dense equivalent.

    MXU passes scale with live blocks only — the TPU analogue of the paper's
    LUT reduction. Recorded in EXPERIMENTS.md §Perf.
    """
    k_dense = plan["n_blocks_total"] * plan["block"]
    k_live = plan["n_blocks_live"] * plan["block"]
    n = plan["out_dim"]

    def passes(kdim: int) -> int:
        return (
            max(1, -(-batch // bm))
            * max(1, -(-n // bn))
            * max(1, -(-kdim // bk))
        )

    fp = mm.vmem_footprint(bm, bk, bn)
    return {
        "dense_mxu_passes": passes(k_dense),
        "sparse_mxu_passes": passes(k_live),
        "pass_reduction": 1.0 - passes(k_live) / passes(k_dense),
        "vmem_bytes_per_step": fp["vmem_bytes"],
        "elision_ratio": plan["elision_ratio"],
    }
