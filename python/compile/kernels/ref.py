"""Pure-jnp reference oracle for the Pallas kernels (L1 correctness ground
truth).

Every Pallas kernel in this package has an entry here implemented with plain
jax.numpy / lax ops only — no pallas, no custom control flow. pytest (and the
hypothesis sweeps) assert `assert_allclose(kernel(...), ref(...))` across
shapes and dtypes; the rust integration tests then check the PJRT-executed
artifact against tensors produced by these same functions, so one oracle
anchors all three layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def matmul(x: jnp.ndarray, w_t: jnp.ndarray) -> jnp.ndarray:
    """y = x @ w_t  with  x:[B, IN], w_t:[IN, OUT]."""
    return jnp.dot(x, w_t, preferred_element_type=jnp.float32)


def matmul_bias(x: jnp.ndarray, w_t: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return matmul(x, w_t) + b[None, :]


def masked_matmul(x: jnp.ndarray, w_t: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Dense oracle for the engine-free sparse matmul: zeros behave exactly
    like pruned connections."""
    return jnp.dot(x, w_t * mask, preferred_element_type=jnp.float32)


def conv2d_nhwc(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """VALID conv, x:[B,H,W,Cin], w:[KH,KW,Cin,Cout] -> [B,H',W',Cout]."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def im2col(x: jnp.ndarray, kh: int, kw: int) -> jnp.ndarray:
    """Unfold VALID patches: [B,H,W,C] -> [B, H', W', KH*KW*C].

    Patch layout is (kh, kw, c) row-major — the layout the Pallas matmul
    kernels and the rust-side weight packer both assume (DESIGN.md §3).
    """
    b, h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(x[:, i : i + oh, j : j + ow, :])
    return jnp.concatenate(cols, axis=-1).reshape(b, oh, ow, kh * kw * c)


def conv2d_im2col(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Conv as im2col + matmul — bit-identical path to the Pallas conv."""
    kh, kw, cin, cout = w.shape
    cols = im2col(x, kh, kw)  # [B, OH, OW, KH*KW*Cin]
    b, oh, ow, patch = cols.shape
    wm = w.reshape(kh * kw * cin, cout)
    out = jnp.dot(cols.reshape(-1, patch), wm, preferred_element_type=jnp.float32)
    return out.reshape(b, oh, ow, cout)


def maxpool2x2(x: jnp.ndarray) -> jnp.ndarray:
    """2x2/stride-2 max pooling, NHWC."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


def relu(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.maximum(x, 0.0)


def pack_sparse_blocks(
    w_t: np.ndarray, mask: np.ndarray, block: int
) -> tuple[np.ndarray, list[int]]:
    """Build-time block packing for the engine-free sparse kernel.

    Partition the IN axis of w_t:[IN, OUT] into `block`-row groups; drop
    groups whose mask rows are all zero. Returns the packed dense weights
    [n_live*block, OUT] and the static list of surviving block indices.
    This mirrors the FPGA flow where pruned weights synthesise to *nothing*:
    the surviving indices become constants in the lowered HLO, never data.
    """
    inn, out = w_t.shape
    if inn % block != 0:
        pad = block - inn % block
        w_t = np.concatenate([w_t, np.zeros((pad, out), w_t.dtype)], axis=0)
        mask = np.concatenate([mask, np.zeros((pad, out), mask.dtype)], axis=0)
        inn += pad
    n_blocks = inn // block
    live: list[int] = []
    for i in range(n_blocks):
        blk = mask[i * block : (i + 1) * block]
        if np.any(blk != 0):
            live.append(i)
    if not live:  # degenerate fully-pruned layer: keep one zero block
        live = [0]
    packed = np.concatenate(
        [(w_t * mask)[i * block : (i + 1) * block] for i in live], axis=0
    )
    return packed.astype(np.float32), live


def sparse_matmul_packed_ref(
    x: np.ndarray, packed: np.ndarray, live: list[int], block: int, out_dim: int
) -> np.ndarray:
    """Oracle for the packed engine-free matmul (numpy, no jax)."""
    b = x.shape[0]
    acc = np.zeros((b, out_dim), np.float32)
    for k, blk_idx in enumerate(live):
        xs = x[:, blk_idx * block : (blk_idx + 1) * block]
        if xs.shape[1] < block:  # padded tail block
            xs = np.pad(xs, ((0, 0), (0, block - xs.shape[1])))
        acc += xs @ packed[k * block : (k + 1) * block]
    return acc
