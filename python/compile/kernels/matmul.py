"""L1 Pallas kernels: tiled (quantised) matmul for the dataflow layers.

TPU adaptation of the paper's LUT-mapped compute (DESIGN.md §3):

- the FPGA's PE/SIMD unroll becomes an (bm, bk, bn) VMEM tile schedule —
  BlockSpec index maps express the HBM->VMEM streaming the FPGA did with
  AXI-stream FIFOs;
- tiles default to MXU-friendly shapes (lane dim 128, sublane 8); LeNet's
  small matrices are zero-padded up to tile multiples at trace time (static
  pads, free at run time after XLA folds them);
- kernels MUST run with interpret=True here: the CPU PJRT client cannot
  execute Mosaic custom-calls. Real-TPU numbers are estimated from the VMEM
  footprint + MXU occupancy recorded by `vmem_footprint()` (EXPERIMENTS.md
  §Perf).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile shapes: sublane x lane = 8 x 128 is the MXU-native layout.
DEF_BM = 8
DEF_BK = 128
DEF_BN = 128

INTERPRET = True  # CPU-PJRT constraint; see module docstring.


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


#: VMEM budget for the X tile of one grid step (bytes). A TPU core has
#: ~16 MB of VMEM; 4 MB for the streaming operand leaves room for the
#: weight tile, output tile and double buffering.
VMEM_X_BUDGET = 4 << 20
#: Hard cap on the sublane axis so every problem retains tiling structure.
MAX_BM = 2048


def auto_tiles(m: int, k: int, n: int) -> tuple:
    """Tile heuristic for the problem shape (perf pass, EXPERIMENTS.md §Perf).

    LeNet's matrices are far smaller than one MXU-native (8,128,128) tile;
    padding every axis to the default tiles wasted up to ~100x MACs on
    conv1 (K 25->128, N 6->128) and, worse for the CPU-interpret path,
    multiplied the number of grid steps (each step is one iteration of the
    lowered while loop; measured ~0.4-1.5 ms per step on this CPU).

    Policy: round K and N to the next power of two (lane-friendly, single
    k-step when possible), then grow the sublane axis `bm` until the X
    tile hits the VMEM budget — fewer, fatter grid steps. Measured on the
    served b32 model: 3.69 s -> 62.5 ms (bm<=128) -> 6.8 ms (VMEM-budget
    bm) per forward; see EXPERIMENTS.md §Perf for the iteration log.
    """
    bk = max(8, min(512, _next_pow2(k)))
    bn = max(8, min(128, _next_pow2(n)))
    vmem_rows = max(8, VMEM_X_BUDGET // (bk * 4))
    bm = max(8, min(min(MAX_BM, _next_pow2(vmem_rows)), _next_pow2(m)))
    return bm, bk, bn


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    """Zero-pad `axis` of x up to a multiple of `mult` (static, trace time)."""
    size = x.shape[axis]
    rem = size % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, mult - rem)
    return jnp.pad(x, pads)


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ w[k,j].

    The k axis is the reduction; the output block is revisited nk times and
    accumulated in place (initialised at k == 0).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul(
    x: jnp.ndarray,
    w_t: jnp.ndarray,
    *,
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """y = x @ w_t via the tiled Pallas kernel.  x:[B,IN], w_t:[IN,OUT].

    Tiles default to `auto_tiles` for the problem shape (see §Perf);
    shapes are padded to tile multiples and the result sliced back.
    """
    b, inn = x.shape
    inn2, out = w_t.shape
    assert inn == inn2, f"inner dims mismatch {inn} vs {inn2}"
    abm, abk, abn = auto_tiles(b, inn, out)
    bm, bk, bn = bm or abm, bk or abk, bn or abn

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w_t, 0, bk), 1, bn)
    m, kdim = xp.shape
    _, n = wp.shape
    nk = kdim // bk

    grid = (m // bm, n // bn, nk)
    out_padded = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out_padded[:b, :out]


def _matmul_int8_kernel(x_ref, wq_ref, scale_ref, o_ref, *, nk: int):
    """Quantised variant: weights arrive as int8 codes + per-column scale and
    are dequantised in VMEM — the accelerator-side analogue of baking int4/8
    codes into logic and widening only at the accumulator."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = wq_ref[...].astype(jnp.float32) * scale_ref[...]
    o_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)


def matmul_int8(
    x: jnp.ndarray,
    w_codes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool = INTERPRET,
) -> jnp.ndarray:
    """y = x @ (codes * scale).  codes:[IN,OUT] int8, scale:[1,OUT] f32."""
    b, inn = x.shape
    inn2, out = w_codes.shape
    assert inn == inn2
    abm, abk, abn = auto_tiles(b, inn, out)
    bm, bk, bn = bm or abm, bk or abk, bn or abn
    assert scale.shape == (1, out), f"scale must be [1,OUT], got {scale.shape}"

    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w_codes, 0, bk), 1, bn)
    sp = _pad_to(scale, 1, bn)
    m, kdim = xp.shape
    _, n = wp.shape
    nk = kdim // bk

    grid = (m // bm, n // bn, nk)
    out_padded = pl.pallas_call(
        functools.partial(_matmul_int8_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(xp, wp, sp)
    return out_padded[:b, :out]


def vmem_footprint(
    bm: int = DEF_BM, bk: int = DEF_BK, bn: int = DEF_BN, bytes_per_el: int = 4
) -> dict:
    """Static VMEM/MXU occupancy estimate for one grid step (perf deliverable).

    Returned fields:
      vmem_bytes   — x-tile + w-tile + o-tile resident bytes;
      mxu_passes   — 128x128 MXU invocations per step;
      mxu_util     — fraction of MXU lanes doing useful work for these tiles.
    """
    vmem = (bm * bk + bk * bn + bm * bn) * bytes_per_el
    passes = max(1, (bk // 128) * (bn // 128)) * max(1, bm // 8)
    util = min(1.0, bm / 8) * min(1.0, bk / 128) * min(1.0, bn / 128)
    return {"vmem_bytes": vmem, "mxu_passes": passes, "mxu_util": util}
