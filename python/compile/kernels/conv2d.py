"""L1: convolution kernels (sliding-window unit + matmul, FINN-style).

FINN decomposes conv into SWU (sliding-window unit, pure wiring) followed by
an MVAU matmul. We keep the same decomposition: `im2col` is static slicing +
concat (wiring — free on the FPGA, constant-folded slices in HLO), and the
MACs run through the Pallas matmul kernels, dense or engine-free sparse.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm
from . import ref
from . import sparse_matmul as sp


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int | None = None,
    bk: int | None = None,
    bn: int | None = None,
    interpret: bool = mm.INTERPRET,
) -> jnp.ndarray:
    """VALID conv via SWU (im2col wiring) + Pallas matmul.

    x:[B,H,W,Cin], w:[KH,KW,Cin,Cout] -> [B,OH,OW,Cout].
    """
    kh, kw, cin, cout = w.shape
    cols = ref.im2col(x, kh, kw)  # [B, OH, OW, KH*KW*Cin]
    b, oh, ow, patch = cols.shape
    y = mm.matmul(
        cols.reshape(b * oh * ow, patch),
        w.reshape(patch, cout),
        bm=bm,
        bk=bk,
        bn=bn,
        interpret=interpret,
    )
    return y.reshape(b, oh, ow, cout)


def conv2d_sparse(
    x: jnp.ndarray,
    plan: dict,
    kh: int,
    kw: int,
    *,
    interpret: bool = mm.INTERPRET,
) -> jnp.ndarray:
    """Engine-free sparse conv: SWU wiring + packed sparse matmul.

    `plan` is `sp.plan_sparse_matmul` of the [KH*KW*Cin, Cout] weight matrix;
    zero SIMD-blocks of the patch axis are never materialised.
    """
    cols = ref.im2col(x, kh, kw)
    b, oh, ow, patch = cols.shape
    assert patch == plan["in_dim"], (patch, plan["in_dim"])
    y = sp.sparse_matmul(cols.reshape(b * oh * ow, patch), plan, interpret=interpret)
    return y.reshape(b, oh, ow, plan["out_dim"])


def _maxpool_kernel(x_ref, o_ref):
    """2x2/2 max pool over one [B,H,W,C] block (whole-tensor block)."""
    x = x_ref[...]
    a = jnp.maximum(x[:, 0::2, 0::2, :], x[:, 0::2, 1::2, :])
    b = jnp.maximum(x[:, 1::2, 0::2, :], x[:, 1::2, 1::2, :])
    o_ref[...] = jnp.maximum(a, b)


def maxpool2x2(x: jnp.ndarray, *, interpret: bool = mm.INTERPRET) -> jnp.ndarray:
    """Pallas 2x2/stride-2 max pooling; H and W must be even (LeNet's are)."""
    b, h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, f"odd spatial dims {x.shape}"
    return pl.pallas_call(
        _maxpool_kernel,
        out_shape=jax.ShapeDtypeStruct((b, h // 2, w // 2, c), x.dtype),
        interpret=interpret,
    )(x)
