"""L1 Pallas kernels + pure-jnp oracle (build-time only; see DESIGN.md §3)."""
from . import conv2d, matmul, ref, sparse_matmul  # noqa: F401
