"""Build-time pruning: global magnitude + per-layer targeted (paper Fig. 1).

The DSE workflow starts from *global magnitude pruning as a reference*
(Sec. II): one threshold across all weight tensors gives the per-layer
achievable sparsity profile that the rust DSE consumes. After the DSE picks
which layers are sparse-unfolded, `layerwise_prune` re-prunes exactly those
layers at their target sparsity (the "re-sparse fine-tuning" input); the
rest stay dense to preserve accuracy.
"""

from __future__ import annotations

from typing import Dict, Iterable, Tuple

import jax.numpy as jnp
import numpy as np


def flatten_weights(params) -> np.ndarray:
    """All weight magnitudes concatenated (biases excluded — FINN keeps
    thresholds/bias in dedicated logic; only MAC weights prune)."""
    return np.concatenate(
        [np.abs(np.asarray(p["w"])).ravel() for p in params.values()]
    )


def global_magnitude_masks(
    params, sparsity: float, layer_floor: float = 0.02
) -> Dict[str, jnp.ndarray]:
    """One global |w| threshold; keep at least `layer_floor` of each layer.

    The floor prevents the global threshold from deleting an entire small
    layer (conv1 has only 150 weights), which would disconnect the pipeline
    — the hardware equivalent of a dangling stream.
    """
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0,1), got {sparsity}")
    allw = flatten_weights(params)
    thr = float(np.quantile(allw, sparsity)) if sparsity > 0 else -1.0
    masks = {}
    for name, p in params.items():
        w = np.asarray(p["w"])
        m = (np.abs(w) > thr).astype(np.float32)
        keep = m.mean()
        if keep < layer_floor:
            # keep the top `layer_floor` fraction of this layer instead
            k = max(1, int(np.ceil(layer_floor * w.size)))
            idx = np.argpartition(np.abs(w).ravel(), -k)[-k:]
            m = np.zeros(w.size, np.float32)
            m[idx] = 1.0
            m = m.reshape(w.shape)
        masks[name] = jnp.asarray(m)
    return masks


def layerwise_prune(
    params, layer_sparsity: Dict[str, float]
) -> Dict[str, jnp.ndarray]:
    """Per-layer magnitude pruning at the DSE-chosen target sparsities.

    Layers absent from `layer_sparsity` stay dense (mask of ones) — the
    paper keeps non-selected layers dense to preserve accuracy.
    """
    masks = {}
    for name, p in params.items():
        w = np.asarray(p["w"])
        s = float(layer_sparsity.get(name, 0.0))
        if s <= 0.0:
            masks[name] = jnp.ones_like(p["w"])
            continue
        if s >= 1.0:
            raise ValueError(f"layer {name}: sparsity {s} >= 1")
        k = max(1, int(round((1.0 - s) * w.size)))
        idx = np.argpartition(np.abs(w).ravel(), -k)[-k:]
        m = np.zeros(w.size, np.float32)
        m[idx] = 1.0
        masks[name] = jnp.asarray(m.reshape(w.shape))
    return masks


def nm_masks(params, n: int = 2, m: int = 4) -> Dict[str, jnp.ndarray]:
    """N:M structured baseline (what mainstream hardware supports — the
    comparison point motivating unstructured sparsity in the paper intro).

    Keeps the N largest of every M consecutive weights along the input axis.
    """
    masks = {}
    for name, p in params.items():
        w = np.asarray(p["w"])
        flat = w.reshape(-1, w.shape[-1])  # [IN-ish, OUT]
        inn, out = flat.shape
        pad = (-inn) % m
        mag = np.abs(np.pad(flat, ((0, pad), (0, 0))))
        groups = mag.reshape(-1, m, out)  # [G, M, OUT]
        order = np.argsort(groups, axis=1)  # ascending
        mask_g = np.ones_like(groups)
        # zero the (m - n) smallest in each group
        drop = order[:, : m - n, :]
        np.put_along_axis(mask_g, drop, 0.0, axis=1)
        mk = mask_g.reshape(-1, out)[:inn].reshape(w.shape)
        masks[name] = jnp.asarray(mk.astype(np.float32))
    return masks


def sparsity_stats(masks: Dict[str, jnp.ndarray]) -> dict:
    """Per-layer + global keep/nnz statistics (prune_profile.json rows)."""
    layers = {}
    tot_w = 0
    tot_nnz = 0
    for name, m in masks.items():
        m = np.asarray(m)
        nnz = int(m.sum())
        layers[name] = {
            "weights": int(m.size),
            "nnz": nnz,
            "sparsity": 1.0 - nnz / m.size,
        }
        tot_w += m.size
        tot_nnz += nnz
    return {
        "layers": layers,
        "total_weights": tot_w,
        "total_nnz": tot_nnz,
        "global_sparsity": 1.0 - tot_nnz / max(1, tot_w),
    }


def compression_ratio(
    masks: Dict[str, jnp.ndarray], weight_bits: int, fp_bits: int = 32
) -> float:
    """Engine-free compression: fp32 dense bits / (nnz * weight_bits).

    No index-storage term — positions are baked into logic (the paper's
    headline 51.6x combines ~8x from 32->4 bit and ~6.45x from pruning).
    """
    st = sparsity_stats(masks)
    dense_bits = st["total_weights"] * fp_bits
    sparse_bits = max(1, st["total_nnz"] * weight_bits)
    return dense_bits / sparse_bits
