"""Build-time QAT training, pruning calibration and re-sparse fine-tuning.

Hand-rolled Adam (optax is not available in this sandbox); everything jit'd
and deterministic in the seed. Three entry points used by aot.py:

  train_qat      — dense W4A4 QAT from scratch (Table I dense accuracy);
  prune_profile  — global-magnitude sweep: sparsity -> accuracy + per-layer
                   nnz, the reference the rust DSE starts from (Fig. 1);
  finetune       — re-sparse fine-tuning with the DSE-chosen fixed masks
                   (paper: only layers selected for sparse-unfolding).
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as dataset
from . import model as M
from . import prune


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    mhat_scale = 1.0 / (1 - b1**tf)
    vhat_scale = 1.0 / (1 - b2**tf)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


@functools.partial(jax.jit, static_argnames=("use_masks",))
def _train_step(params, opt, xb, yb, masks, lr, use_masks: bool):
    mk = masks if use_masks else None

    def loss_fn(p):
        return cross_entropy(M.forward(p, xb, mk), yb)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    if use_masks:
        # Pruned weights stay pruned: gradient is masked so fine-tuning only
        # moves surviving weights (fixed-topology re-sparse fine-tune).
        grads = {
            name: {
                "w": g["w"] * masks[name],
                "b": g["b"],
            }
            for name, g in grads.items()
        }
    params, opt = adam_update(params, grads, opt, lr=lr)
    return params, opt, loss


@functools.partial(jax.jit, static_argnames=("use_masks",))
def _eval_logits(params, x, masks, use_masks: bool):
    return M.forward(params, x, masks if use_masks else None)


def evaluate(params, x, y, masks=None, batch: int = 512) -> float:
    """Top-1 accuracy of the QAT reference path."""
    correct = 0
    for i in range(0, x.shape[0], batch):
        xb = jnp.asarray(x[i : i + batch])
        logits = _eval_logits(params, xb, masks, masks is not None)
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == jnp.asarray(y[i : i + batch])))
    return correct / x.shape[0]


def train_qat(
    x_train,
    y_train,
    x_test,
    y_test,
    steps: int = 700,
    batch: int = 96,
    lr: float = 2e-3,
    seed: int = 0,
    masks: Optional[Dict[str, jnp.ndarray]] = None,
    params=None,
    log_every: int = 100,
    log=print,
) -> Tuple[dict, list]:
    """QAT training loop; returns (params, loss_log)."""
    if params is None:
        params = M.init_params(seed)
    use_masks = masks is not None
    if masks is None:
        masks = M.ones_masks(params)  # dummy pytree for jit signature
    opt = adam_init(params)
    it = dataset.batches(x_train, y_train, batch, seed + 1)
    losses = []
    t0 = time.time()
    for step in range(1, steps + 1):
        xb, yb = next(it)
        # cosine decay
        lr_t = lr * 0.5 * (1 + np.cos(np.pi * step / steps))
        params, opt, loss = _train_step(
            params, opt, jnp.asarray(xb), jnp.asarray(yb), masks, lr_t, use_masks
        )
        losses.append(float(loss))
        if log_every and step % log_every == 0:
            acc = evaluate(params, x_test[:512], y_test[:512], masks if use_masks else None)
            log(
                f"  step {step:4d}/{steps}  loss {float(loss):.4f}  "
                f"val@512 {100*acc:.2f}%  ({time.time()-t0:.1f}s)"
            )
    return params, losses


def prune_profile(
    params,
    x_test,
    y_test,
    sparsities=(0.5, 0.6, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95),
    eval_n: int = 1024,
    log=print,
) -> dict:
    """Global-magnitude reference sweep (no fine-tune): the DSE's input.

    For each global sparsity: accuracy of the pruned+quantised model and the
    per-layer achieved sparsity. The rust DSE uses this to pick per-layer
    sparsity targets that respect the accuracy budget.
    """
    rows = []
    for s in sparsities:
        masks = prune.global_magnitude_masks(params, s)
        acc = evaluate(params, x_test[:eval_n], y_test[:eval_n], masks)
        st = prune.sparsity_stats(masks)
        rows.append(
            {
                "global_sparsity_target": s,
                "global_sparsity": st["global_sparsity"],
                "accuracy": acc,
                "layers": {
                    name: round(v["sparsity"], 6) for name, v in st["layers"].items()
                },
            }
        )
        log(f"  prune sweep s={s:.2f}: acc {100*acc:.2f}%  global {st['global_sparsity']:.3f}")
    return {"rows": rows}


def finetune(
    params,
    masks,
    x_train,
    y_train,
    x_test,
    y_test,
    steps: int = 400,
    batch: int = 96,
    lr: float = 5e-4,
    seed: int = 7,
    log=print,
) -> Tuple[dict, list]:
    """Re-sparse fine-tuning: masked gradients, fixed topology."""
    return train_qat(
        x_train,
        y_train,
        x_test,
        y_test,
        steps=steps,
        batch=batch,
        lr=lr,
        seed=seed,
        masks=masks,
        params=params,
        log=log,
    )
