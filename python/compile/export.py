"""Build-time exporters: LSTW tensor binaries + JSON sidecars.

LSTW ("LogicSparse Tensor Weights") is the tensor interchange between the
python compile path and the rust runtime — serde/npy crates are not
available offline, so the format is deliberately trivial and implemented
twice (here and in rust `util::lstw`), with round-trip tests on both sides.

Layout (all little-endian):
  magic   8 bytes  b"LSTW0001"
  u32     n_tensors
  per tensor:
    u16   name_len,  name utf-8 bytes
    u8    dtype      (0=f32, 1=i32, 2=i8, 3=u8)
    u8    ndim
    u32   dims[ndim]
    u64   payload bytes
    raw   payload (C-order)
"""

from __future__ import annotations

import json
import struct
from pathlib import Path
from typing import Dict

import numpy as np

MAGIC = b"LSTW0001"

_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.int8): 2,
    np.dtype(np.uint8): 3,
}
_DTYPES_INV = {v: k for k, v in _DTYPES.items()}


def write_lstw(path: str | Path, tensors: Dict[str, np.ndarray]) -> None:
    """Write a name->tensor dict; iteration order is preserved."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            payload = arr.tobytes()
            f.write(struct.pack("<Q", len(payload)))
            f.write(payload)


def read_lstw(path: str | Path) -> Dict[str, np.ndarray]:
    """Read back (python-side round-trip partner for the tests)."""
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (n,) = struct.unpack("<I", f.read(4))
        out: Dict[str, np.ndarray] = {}
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode("utf-8")
            dt, nd = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            (nbytes,) = struct.unpack("<Q", f.read(8))
            arr = np.frombuffer(f.read(nbytes), dtype=_DTYPES_INV[dt])
            out[name] = arr.reshape(dims).copy()
        return out


def write_json(path: str | Path, obj) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump(obj, f, indent=2, sort_keys=True)
        f.write("\n")


def read_json(path: str | Path):
    with open(path) as f:
        return json.load(f)


def export_params(path: str | Path, params, masks) -> None:
    """Flatten params+masks into one LSTW file (names `<layer>.w/.b/.mask`)."""
    tensors: Dict[str, np.ndarray] = {}
    for name, p in params.items():
        tensors[f"{name}.w"] = np.asarray(p["w"], np.float32)
        tensors[f"{name}.b"] = np.asarray(p["b"], np.float32)
    for name, m in masks.items():
        tensors[f"{name}.mask"] = np.asarray(m, np.uint8)
    write_lstw(path, tensors)


def export_testset(path: str | Path, x: np.ndarray, y: np.ndarray) -> None:
    """Test images + labels for the rust-side accuracy evaluation."""
    write_lstw(
        path,
        {
            "images": np.asarray(x, np.float32),
            "labels": np.asarray(y, np.int32),
        },
    )
