"""Synthetic MNIST-like digit dataset (build-time substrate).

The paper evaluates LeNet-5 on MNIST. This sandbox has no dataset access, so
we procedurally render a 10-class digit task with the same tensor shapes
(28x28x1, labels 0..9) and enough intra-class variation (affine jitter,
stroke-width variation, pixel noise) that the QAT -> prune -> re-sparse
fine-tune pipeline is exercised on a genuinely learnable problem. The
substitution is recorded in DESIGN.md §2.

Rendering is fully vectorised numpy: each sample applies a random inverse
affine map from the 28x28 canvas to a 7x5 glyph bitmap and bilinearly
samples it, then adds noise. Deterministic in the seed.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

# Classic 7x5 bitmap font, one string row per scanline per digit.
_GLYPHS_TXT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00010", "00100", "01000", "11111"],
    3: ["11111", "00010", "00100", "00010", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}

GLYPH_H, GLYPH_W = 7, 5
IMG = 28
NUM_CLASSES = 10


def glyph_array(digit: int) -> np.ndarray:
    """7x5 float bitmap for one digit."""
    rows = _GLYPHS_TXT[digit]
    return np.array([[float(c) for c in r] for r in rows], dtype=np.float32)


_GLYPHS = None


def _glyphs() -> np.ndarray:
    global _GLYPHS
    if _GLYPHS is None:
        _GLYPHS = np.stack([glyph_array(d) for d in range(NUM_CLASSES)])
    return _GLYPHS


def render_batch(
    labels: np.ndarray,
    rng: np.random.Generator,
    noise: float = 0.08,
    jitter_px: float = 3.0,
    scale_lo: float = 2.2,
    scale_hi: float = 3.4,
    rot_deg: float = 12.0,
) -> np.ndarray:
    """Render a batch of digits -> float32 [B, 28, 28, 1] in [0, 1].

    Each sample gets an independent random scale, rotation, translation and
    stroke softness; the glyph is bilinearly sampled through the inverse
    affine map so edges are anti-aliased (closer to handwriting than crisp
    block glyphs).
    """
    b = labels.shape[0]
    glyphs = _glyphs()[labels]  # [B, 7, 5]

    scale = rng.uniform(scale_lo, scale_hi, size=b).astype(np.float32)
    theta = np.deg2rad(rng.uniform(-rot_deg, rot_deg, size=b)).astype(np.float32)
    # Shear adds a handwriting-like slant.
    shear = rng.uniform(-0.15, 0.15, size=b).astype(np.float32)
    tx = rng.uniform(-jitter_px, jitter_px, size=b).astype(np.float32)
    ty = rng.uniform(-jitter_px, jitter_px, size=b).astype(np.float32)

    # Output pixel grid, centred.
    ys, xs = np.meshgrid(
        np.arange(IMG, dtype=np.float32), np.arange(IMG, dtype=np.float32), indexing="ij"
    )
    yc = ys - (IMG - 1) / 2.0
    xc = xs - (IMG - 1) / 2.0

    cos_t = np.cos(theta)[:, None, None]
    sin_t = np.sin(theta)[:, None, None]
    sc = scale[:, None, None]
    sh = shear[:, None, None]

    # Inverse map canvas -> glyph coordinates.
    u = (cos_t * (xc - tx[:, None, None]) + sin_t * (yc - ty[:, None, None])) / sc
    v = (-sin_t * (xc - tx[:, None, None]) + cos_t * (yc - ty[:, None, None])) / sc
    u = u - sh * v

    gu = u + (GLYPH_W - 1) / 2.0
    gv = v + (GLYPH_H - 1) / 2.0

    # Bilinear sample with zero padding outside the glyph.
    u0 = np.floor(gu).astype(np.int32)
    v0 = np.floor(gv).astype(np.int32)
    du = gu - u0
    dv = gv - v0

    def tap(vv: np.ndarray, uu: np.ndarray) -> np.ndarray:
        inside = (vv >= 0) & (vv < GLYPH_H) & (uu >= 0) & (uu < GLYPH_W)
        vvc = np.clip(vv, 0, GLYPH_H - 1)
        uuc = np.clip(uu, 0, GLYPH_W - 1)
        bidx = np.arange(b)[:, None, None]
        vals = glyphs[bidx, vvc, uuc]
        return np.where(inside, vals, 0.0).astype(np.float32)

    img = (
        tap(v0, u0) * (1 - du) * (1 - dv)
        + tap(v0, u0 + 1) * du * (1 - dv)
        + tap(v0 + 1, u0) * (1 - du) * dv
        + tap(v0 + 1, u0 + 1) * du * dv
    )

    # Stroke softness: per-sample gamma on intensity.
    gamma = rng.uniform(0.7, 1.5, size=b).astype(np.float32)[:, None, None]
    img = np.clip(img, 0.0, 1.0) ** gamma

    # Additive Gaussian noise + salt specks, then clip.
    img = img + rng.normal(0.0, noise, size=img.shape).astype(np.float32)
    salt = rng.random(img.shape) < 0.003
    img = np.where(salt, np.float32(1.0), img)
    img = np.clip(img, 0.0, 1.0).astype(np.float32)
    return img[..., None]


def make_dataset(
    n_train: int = 6144,
    n_test: int = 2048,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Balanced train/test split with disjoint RNG streams.

    Returns (x_train, y_train, x_test, y_test); images float32 [N,28,28,1].
    """
    rng_train = np.random.default_rng(seed)
    rng_test = np.random.default_rng(seed + 10_000)

    y_train = np.arange(n_train, dtype=np.int32) % NUM_CLASSES
    rng_train.shuffle(y_train)
    y_test = np.arange(n_test, dtype=np.int32) % NUM_CLASSES
    rng_test.shuffle(y_test)

    x_train = render_batch(y_train, rng_train)
    x_test = render_batch(y_test, rng_test)
    return x_train, y_train, x_test, y_test


def batches(x: np.ndarray, y: np.ndarray, batch: int, seed: int):
    """Infinite shuffled batch iterator (numpy-side, cheap)."""
    rng = np.random.default_rng(seed)
    n = x.shape[0]
    while True:
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = perm[i : i + batch]
            yield x[idx], y[idx]
