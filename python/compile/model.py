"""L2: LeNet-5 QNN — the paper's evaluation network — in JAX.

Two forward paths share one set of parameters:

- `forward` — pure-jnp (via kernels.ref): used for QAT training / pruning /
  fine-tuning where trace speed matters and gradients must flow (STE);
- `forward_accel` — the *accelerator* path: every MAC layer goes through the
  L1 Pallas kernels, with per-layer style decided by the rust DSE (folded
  dense, unrolled dense, or engine-free unrolled sparse). This is the path
  `aot.py` lowers to HLO for the rust runtime, so what the coordinator
  serves is exactly what the kernels tests validated.

Topology (FINN-flavoured LeNet-5 on 28x28x1, VALID convs):
  conv1 1->6 k5  -> relu/q -> maxpool2
  conv2 6->16 k5 -> relu/q -> maxpool2
  fc1 256->120   -> relu/q
  fc2 120->84    -> relu/q
  fc3 84->10     -> logits
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import quant
from .kernels import conv2d as kconv
from .kernels import matmul as kmm
from .kernels import ref
from .kernels import sparse_matmul as ksp

NUM_CLASSES = 10
IMG = 28


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Static description of one MAC layer (mirrors rust graph::Node)."""

    name: str
    kind: str  # "conv" | "fc"
    cin: int
    cout: int
    k: int  # kernel size (conv) or 1
    ifm: int  # input spatial dim (conv) or 1
    ofm: int  # output spatial dim (conv) or 1

    @property
    def weight_count(self) -> int:
        return self.cout * self.cin * self.k * self.k

    @property
    def fold_in(self) -> int:
        """SIMD axis extent (K^2 * Cin for conv, IN for fc)."""
        return self.cin * self.k * self.k

    @property
    def macs_per_frame(self) -> int:
        return self.ofm * self.ofm * self.weight_count


# Canonical LeNet-5 layer list — single source of truth, exported to
# graph.json and re-built independently by rust::graph::builder (tested
# against each other in the integration tests).
LAYERS: List[LayerSpec] = [
    LayerSpec("conv1", "conv", 1, 6, 5, 28, 24),
    LayerSpec("conv2", "conv", 6, 16, 5, 12, 8),
    LayerSpec("fc1", "fc", 256, 120, 1, 1, 1),
    LayerSpec("fc2", "fc", 120, 84, 1, 1, 1),
    LayerSpec("fc3", "fc", 84, 10, 1, 1, 1),
]

LAYER_BY_NAME = {l.name: l for l in LAYERS}


def init_params(seed: int = 0) -> Dict[str, Dict[str, jnp.ndarray]]:
    """He-init parameters. Conv weights [KH,KW,Cin,Cout]; fc [IN,OUT]."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, Dict[str, jnp.ndarray]] = {}
    for spec in LAYERS:
        key, kw = jax.random.split(key)
        fan_in = spec.fold_in
        std = float(np.sqrt(2.0 / fan_in))
        if spec.kind == "conv":
            shape = (spec.k, spec.k, spec.cin, spec.cout)
        else:
            shape = (spec.cin, spec.cout)
        params[spec.name] = {
            "w": jax.random.normal(kw, shape, jnp.float32) * std,
            "b": jnp.zeros((spec.cout,), jnp.float32),
        }
    return params


def ones_masks(params) -> Dict[str, jnp.ndarray]:
    return {name: jnp.ones_like(p["w"]) for name, p in params.items()}


def _qw(w: jnp.ndarray, mask: Optional[jnp.ndarray], wbits: int) -> jnp.ndarray:
    """Prune -> per-output-channel fake-quant -> re-mask.

    Output channel is the LAST axis in both layouts; quant.weight_scale
    expects channels leading, so move it for the scale computation.
    """
    wm = w if mask is None else w * mask
    wmc = jnp.moveaxis(wm, -1, 0)
    wq = quant.fake_quant_weight(wmc, wbits, per_channel=True)
    wq = jnp.moveaxis(wq, 0, -1)
    return wq if mask is None else wq * mask


def forward(
    params,
    x: jnp.ndarray,
    masks: Optional[Dict[str, jnp.ndarray]] = None,
    wbits: int = quant.DEFAULT_WEIGHT_BITS,
    abits: int = quant.DEFAULT_ACT_BITS,
    quantize: bool = True,
) -> jnp.ndarray:
    """Reference/training forward: x [B,28,28,1] -> logits [B,10]."""

    def qa(h):
        return quant.fake_quant_act(h, abits) if quantize else ref.relu(h)

    def w_of(name):
        w = params[name]["w"]
        m = None if masks is None else masks.get(name)
        return _qw(w, m, wbits) if quantize else (w if m is None else w * m)

    h = ref.conv2d_nhwc(x, w_of("conv1")) + params["conv1"]["b"]
    h = ref.maxpool2x2(qa(h))
    h = ref.conv2d_nhwc(h, w_of("conv2")) + params["conv2"]["b"]
    h = ref.maxpool2x2(qa(h))
    h = h.reshape(h.shape[0], -1)  # [B, 256], (h, w, c) row-major
    h = qa(ref.matmul_bias(h, w_of("fc1"), params["fc1"]["b"]))
    h = qa(ref.matmul_bias(h, w_of("fc2"), params["fc2"]["b"]))
    return ref.matmul_bias(h, w_of("fc3"), params["fc3"]["b"])


# --------------------------------------------------------------------------
# Accelerator path (what gets lowered to HLO and served by rust).
# --------------------------------------------------------------------------

#: Layer styles assigned by the rust DSE (folding_config.json):
#:   folded          — time-multiplexed PE/SIMD, dense weights from BRAM;
#:   unrolled_dense  — fully unrolled, dense weights baked;
#:   unrolled_sparse — fully unrolled + engine-free unstructured sparsity;
#:   partial_sparse  — partially unrolled with sparse packing.
STYLES = ("folded", "unrolled_dense", "unrolled_sparse", "partial_sparse")


def build_accel_fn(
    params,
    masks: Dict[str, jnp.ndarray],
    styles: Dict[str, str],
    wbits: int = quant.DEFAULT_WEIGHT_BITS,
    abits: int = quant.DEFAULT_ACT_BITS,
    block: int = ksp.DEFAULT_BLOCK,
    interpret: bool = kmm.INTERPRET,
):
    """Close over baked (pruned + quantised) weights and return a jittable
    `x -> logits` whose MACs all run through the L1 Pallas kernels.

    Weight values are resolved to numpy *here* (build time). Layers styled
    `unrolled_sparse`/`partial_sparse` get an engine-free plan: their lowered
    HLO contains only surviving SIMD blocks.
    """
    for name, s in styles.items():
        if s not in STYLES:
            raise ValueError(f"unknown style {s!r} for layer {name}")

    baked: Dict[str, dict] = {}
    for spec in LAYERS:
        name = spec.name
        w = np.asarray(_qw(params[name]["w"], masks.get(name), wbits))
        b = np.asarray(params[name]["b"])
        style = styles.get(name, "folded")
        w_t = w.reshape(spec.fold_in, spec.cout)
        m_t = np.asarray(masks[name]).reshape(spec.fold_in, spec.cout)
        entry = {"b": jnp.asarray(b), "style": style, "spec": spec}
        if style in ("unrolled_sparse", "partial_sparse"):
            entry["plan"] = ksp.plan_sparse_matmul(w_t, m_t, block)
        else:
            entry["w_t"] = jnp.asarray(w_t)
        baked[name] = entry

    def qa(h):
        return quant.fake_quant_act(h, abits)

    def mac(name: str, h: jnp.ndarray) -> jnp.ndarray:
        e = baked[name]
        spec: LayerSpec = e["spec"]
        if spec.kind == "conv":
            if e["style"] in ("unrolled_sparse", "partial_sparse"):
                y = kconv.conv2d_sparse(h, e["plan"], spec.k, spec.k, interpret=interpret)
            else:
                w4 = e["w_t"].reshape(spec.k, spec.k, spec.cin, spec.cout)
                y = kconv.conv2d(h, w4, interpret=interpret)
            return y + e["b"]
        if e["style"] in ("unrolled_sparse", "partial_sparse"):
            y = ksp.sparse_matmul(h, e["plan"], interpret=interpret)
        else:
            y = kmm.matmul(h, e["w_t"], interpret=interpret)
        return y + e["b"]

    def fn(x: jnp.ndarray) -> jnp.ndarray:
        h = kconv.maxpool2x2(qa(mac("conv1", x)), interpret=interpret)
        h = kconv.maxpool2x2(qa(mac("conv2", h)), interpret=interpret)
        h = h.reshape(h.shape[0], -1)
        h = qa(mac("fc1", h))
        h = qa(mac("fc2", h))
        return mac("fc3", h)

    return fn, baked


def graph_dict(batch: int = 1) -> dict:
    """ONNX-like graph export consumed by rust::graph::import (graph.json)."""
    nodes = []
    for spec in LAYERS:
        nodes.append(
            {
                "name": spec.name,
                "op": spec.kind,
                "cin": spec.cin,
                "cout": spec.cout,
                "k": spec.k,
                "ifm": spec.ifm,
                "ofm": spec.ofm,
                "weights": spec.weight_count,
                "macs_per_frame": spec.macs_per_frame,
            }
        )
        if spec.kind == "conv":
            nodes.append(
                {
                    "name": spec.name + "_pool",
                    "op": "maxpool",
                    "cin": spec.cout,
                    "cout": spec.cout,
                    "k": 2,
                    "ifm": spec.ofm,
                    "ofm": spec.ofm // 2,
                    "weights": 0,
                    "macs_per_frame": 0,
                }
            )
    return {
        "model": "lenet5",
        "dataset": "synthetic-digits(28x28x1,10)",
        "batch": batch,
        "input": [batch, IMG, IMG, 1],
        "output": [batch, NUM_CLASSES],
        "weight_bits": quant.DEFAULT_WEIGHT_BITS,
        "act_bits": quant.DEFAULT_ACT_BITS,
        "nodes": nodes,
    }
