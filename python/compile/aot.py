"""AOT compile driver (Fig. 1 workflow, python half).

Stage 1 (`--stage 1`): train the dense W4A4 LeNet-5, run the global-
magnitude pruning reference sweep, export everything the rust DSE needs
(graph.json, prune_profile.json), the serving test set, and the *dense*
accelerator HLO variants.

Stage 2 (`--stage 2`): consume the rust DSE's folding_config.json —
per-layer styles + sparsity targets — re-prune, re-sparse fine-tune, and
export the *proposed* engine-free sparse HLO variants plus final metrics.

HLO is exported as TEXT (never `.serialize()`): jax >= 0.5 emits protos
with 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Python runs only here, at build time; the rust binary serves from
artifacts/ alone.
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as dataset
from . import export as ex
from . import model as M
from . import prune
from . import quant
from . import train as T

BATCH_VARIANTS = (1, 8, 32)

# Reference global sparsity for the "+Pruning" Table-I rows; the proposed
# row instead uses the per-layer targets from the rust DSE.
REF_GLOBAL_SPARSITY = 0.80


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    print_large_constants=True is LOAD-BEARING: the default HLO printer
    elides big literals as `{...}`, which the parser silently reads back
    as ZEROS — the served model would run with zero weights (bias-only
    logits, ~10% accuracy). The baked engine-free weights must survive the
    text round-trip verbatim.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_accel(params, masks, styles, batch: int) -> str:
    fn, _ = M.build_accel_fn(params, masks, styles)
    spec = jax.ShapeDtypeStruct((batch, M.IMG, M.IMG, 1), jnp.float32)
    lowered = jax.jit(lambda x: (fn(x),)).lower(spec)
    return to_hlo_text(lowered)


def export_hlo_variants(out: Path, tag: str, params, masks, styles, log) -> None:
    for b in BATCH_VARIANTS:
        t0 = time.time()
        text = lower_accel(params, masks, styles, b)
        path = out / f"lenet_{tag}_b{b}.hlo.txt"
        path.write_text(text)
        log(f"  wrote {path.name}  ({len(text)/1e3:.0f} kB, {time.time()-t0:.1f}s)")


def params_to_tensors(params) -> dict:
    t = {}
    for name, p in params.items():
        t[f"{name}.w"] = np.asarray(p["w"], np.float32)
        t[f"{name}.b"] = np.asarray(p["b"], np.float32)
    return t


def tensors_to_params(t: dict) -> dict:
    params = {}
    for key, arr in t.items():
        name, kind = key.rsplit(".", 1)
        if kind in ("w", "b"):
            params.setdefault(name, {})[kind] = jnp.asarray(arr)
    return params


def masks_from_tensors(t: dict) -> dict:
    return {
        key.rsplit(".", 1)[0]: jnp.asarray(arr.astype(np.float32))
        for key, arr in t.items()
        if key.endswith(".mask")
    }


def stage1(out: Path, fast: bool, seed: int, log) -> None:
    log("[stage1] dataset")
    n_train, n_test = (2048, 512) if fast else (6144, 2048)
    steps = 200 if fast else 700
    x_train, y_train, x_test, y_test = dataset.make_dataset(n_train, n_test, seed)

    log("[stage1] dense QAT training")
    params, losses = T.train_qat(
        x_train, y_train, x_test, y_test, steps=steps, seed=seed, log=log
    )
    dense_acc = T.evaluate(params, x_test, y_test)
    log(f"[stage1] dense QAT accuracy: {100*dense_acc:.2f}%")

    log("[stage1] global magnitude pruning reference sweep")
    profile = T.prune_profile(params, x_test, y_test, log=log)
    profile["reference_global_sparsity"] = REF_GLOBAL_SPARSITY

    log("[stage1] exports")
    ex.write_json(out / "graph.json", M.graph_dict())
    ex.write_json(out / "prune_profile.json", profile)
    ex.write_lstw(out / "params_stage1.lstw", params_to_tensors(params))
    ex.export_testset(out / "testset.lstw", x_test, y_test)
    ex.write_json(
        out / "metrics_stage1.json",
        {
            "dense_accuracy": dense_acc,
            "train_steps": steps,
            "final_loss": losses[-1],
            "loss_curve_tail": [round(l, 5) for l in losses[-50:]],
            "n_train": n_train,
            "n_test": n_test,
            "weight_bits": quant.DEFAULT_WEIGHT_BITS,
            "act_bits": quant.DEFAULT_ACT_BITS,
        },
    )

    masks = M.ones_masks(params)
    styles = {l.name: "folded" for l in M.LAYERS}
    log("[stage1] lowering dense accelerator HLO variants")
    export_hlo_variants(out, "dense", params, masks, styles, log)
    log("[stage1] done")


def stage2(out: Path, fast: bool, seed: int, log) -> None:
    cfg_path = out / "folding_config.json"
    if not cfg_path.exists():
        sys.exit(
            f"{cfg_path} missing — run the rust DSE first:\n"
            "  cargo run --release -- dse --artifacts artifacts"
        )
    cfg = ex.read_json(cfg_path)
    params = tensors_to_params(ex.read_lstw(out / "params_stage1.lstw"))

    n_train, n_test = (2048, 512) if fast else (6144, 2048)
    ft_steps = 150 if fast else 450
    x_train, y_train, x_test, y_test = dataset.make_dataset(n_train, n_test, seed)

    # ---- "+Pruning" rows: global magnitude at the reference sparsity ----
    log(f"[stage2] global-pruned fine-tune at s={REF_GLOBAL_SPARSITY}")
    g_masks = prune.global_magnitude_masks(params, REF_GLOBAL_SPARSITY)
    gp_params, _ = T.finetune(
        params, g_masks, x_train, y_train, x_test, y_test, steps=ft_steps, log=log
    )
    acc_pruned_global = T.evaluate(gp_params, x_test, y_test, g_masks)
    log(f"[stage2] global-pruned accuracy: {100*acc_pruned_global:.2f}%")

    # ---- proposed row: per-layer styles + sparsity targets from the DSE ----
    layer_cfg = cfg["layers"]
    styles = {name: c["style"] for name, c in layer_cfg.items()}
    targets = {
        name: float(c.get("target_sparsity", 0.0))
        for name, c in layer_cfg.items()
        if c["style"] in ("unrolled_sparse", "partial_sparse")
    }
    log(f"[stage2] proposed styles: {styles}")
    log(f"[stage2] proposed sparsity targets: {targets}")
    p_masks = prune.layerwise_prune(params, targets)
    pp_params, losses = T.finetune(
        params, p_masks, x_train, y_train, x_test, y_test, steps=ft_steps, log=log
    )
    acc_proposed = T.evaluate(pp_params, x_test, y_test, p_masks)
    log(f"[stage2] proposed accuracy: {100*acc_proposed:.2f}%")

    st_global = prune.sparsity_stats(g_masks)
    st_prop = prune.sparsity_stats(p_masks)
    stage1_metrics = ex.read_json(out / "metrics_stage1.json")

    log("[stage2] exports")
    ex.export_params(out / "params_proposed.lstw", pp_params, p_masks)
    ex.export_params(out / "params_pruned_global.lstw", gp_params, g_masks)
    ex.write_json(
        out / "metrics.json",
        {
            "dense_accuracy": stage1_metrics["dense_accuracy"],
            "pruned_global_accuracy": acc_pruned_global,
            "proposed_accuracy": acc_proposed,
            "finetune_steps": ft_steps,
            "finetune_final_loss": losses[-1],
            "global_masks": st_global,
            "proposed_masks": st_prop,
            "compression_global": prune.compression_ratio(
                g_masks, quant.DEFAULT_WEIGHT_BITS
            ),
            "compression_proposed": prune.compression_ratio(
                p_masks, quant.DEFAULT_WEIGHT_BITS
            ),
            "weight_bits": quant.DEFAULT_WEIGHT_BITS,
            "act_bits": quant.DEFAULT_ACT_BITS,
        },
    )

    log("[stage2] lowering proposed (engine-free sparse) HLO variants")
    export_hlo_variants(out, "proposed", pp_params, p_masks, styles, log)
    # Unfold+Pruning variant: every MAC layer unrolled sparse with the
    # global masks (Table I row 6).
    log("[stage2] lowering unfold+pruning HLO variants")
    all_sparse = {l.name: "unrolled_sparse" for l in M.LAYERS}
    export_hlo_variants(out, "unfold_pruned", gp_params, g_masks, all_sparse, log)
    log("[stage2] done")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--stage", choices=["1", "2", "all"], default="all")
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--fast", action="store_true", help="CI-sized run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    log = print

    t0 = time.time()
    if args.stage in ("1", "all"):
        stage1(out, args.fast, args.seed, log)
    if args.stage in ("2", "all"):
        if args.stage == "all" and not (out / "folding_config.json").exists():
            log("[aot] folding_config.json absent — stopping after stage 1 "
                "(run the rust DSE, then `--stage 2`)")
        else:
            stage2(out, args.fast, args.seed, log)
    log(f"[aot] total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
