"""Pruning: global magnitude semantics, per-layer targets, N:M baseline,
sparsity statistics."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import prune


@pytest.fixture(scope="module")
def params():
    return M.init_params(seed=3)


class TestGlobalMagnitude:
    def test_hits_global_target(self, params):
        for s in [0.5, 0.8]:
            masks = prune.global_magnitude_masks(params, s, layer_floor=0.0)
            st_ = prune.sparsity_stats(masks)
            assert abs(st_["global_sparsity"] - s) < 0.02

    def test_large_layers_prune_more(self, params):
        # Global thresholding prunes fc1 (30k weights, small magnitudes)
        # harder than conv1 (150 weights, larger magnitudes) — exactly the
        # per-layer imbalance the DSE exploits.
        masks = prune.global_magnitude_masks(params, 0.8)
        st_ = prune.sparsity_stats(masks)["layers"]
        assert st_["fc1"]["sparsity"] > st_["conv1"]["sparsity"]

    def test_layer_floor(self, params):
        masks = prune.global_magnitude_masks(params, 0.97, layer_floor=0.05)
        for name, m in masks.items():
            keep = float(np.asarray(m).mean())
            assert keep >= 0.049, f"{name} kept only {keep}"

    def test_rejects_bad_sparsity(self, params):
        with pytest.raises(ValueError):
            prune.global_magnitude_masks(params, 1.0)


class TestLayerwise:
    def test_exact_targets(self, params):
        targets = {"conv1": 0.4, "fc1": 0.85}
        masks = prune.layerwise_prune(params, targets)
        st_ = prune.sparsity_stats(masks)["layers"]
        assert abs(st_["conv1"]["sparsity"] - 0.4) < 0.02
        assert abs(st_["fc1"]["sparsity"] - 0.85) < 0.01
        # untargeted layers stay dense
        assert st_["conv2"]["sparsity"] == 0.0

    def test_keeps_largest(self, params):
        masks = prune.layerwise_prune(params, {"fc2": 0.7})
        w = np.asarray(params["fc2"]["w"])
        m = np.asarray(masks["fc2"])
        kept_min = np.abs(w[m > 0]).min()
        dropped_max = np.abs(w[m == 0]).max()
        assert kept_min >= dropped_max

    @settings(max_examples=10, deadline=None)
    @given(s=st.floats(0.05, 0.95))
    def test_hypothesis_rate(self, params, s):
        masks = prune.layerwise_prune(params, {"fc1": s})
        got = prune.sparsity_stats(masks)["layers"]["fc1"]["sparsity"]
        assert abs(got - s) < 0.02


class TestNM:
    def test_nm_rate(self, params):
        masks = prune.nm_masks(params, 2, 4)
        st_ = prune.sparsity_stats(masks)
        # 2:4 = 50% (up to tail-group effects on non-multiple layers)
        assert abs(st_["global_sparsity"] - 0.5) < 0.05

    def test_group_structure(self):
        p = {"x": {"w": jnp.asarray(np.arange(16, dtype=np.float32).reshape(8, 2))}}
        masks = prune.nm_masks(p, 1, 2)
        m = np.asarray(masks["x"])
        # exactly one kept per group of 2 along the input axis, per column
        groups = m.reshape(4, 2, 2)
        assert (groups.sum(axis=1) == 1).all()


class TestCompression:
    def test_compression_engine_free(self, params):
        masks = prune.layerwise_prune(
            params, {n: 0.845 for n in params}
        )
        c = prune.compression_ratio(masks, weight_bits=4)
        assert 45 < c < 60  # ≈ the paper's 51.6x operating point
