"""L2 model: shapes, ref-vs-accelerator agreement (the path equivalence
the served system relies on), style handling, graph export."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile import prune

RNG = np.random.default_rng(5)


@pytest.fixture(scope="module")
def params():
    return M.init_params(seed=1)


@pytest.fixture(scope="module")
def batch():
    return jnp.asarray(RNG.random((4, 28, 28, 1)).astype(np.float32))


class TestForward:
    def test_shapes(self, params, batch):
        logits = M.forward(params, batch)
        assert logits.shape == (4, 10)
        assert np.isfinite(np.asarray(logits)).all()

    def test_masks_change_output(self, params, batch):
        dense = M.forward(params, batch)
        masks = prune.layerwise_prune(params, {n: 0.9 for n in params})
        pruned = M.forward(params, batch, masks)
        assert not np.allclose(np.asarray(dense), np.asarray(pruned))

    def test_quantize_toggle(self, params, batch):
        q = M.forward(params, batch, quantize=True)
        f = M.forward(params, batch, quantize=False)
        assert not np.allclose(np.asarray(q), np.asarray(f))


class TestAccelPath:
    @pytest.mark.parametrize(
        "styles_fn",
        [
            lambda: {l.name: "folded" for l in M.LAYERS},
            lambda: {l.name: "unrolled_sparse" for l in M.LAYERS},
            lambda: {
                "conv1": "unrolled_sparse",
                "conv2": "partial_sparse",
                "fc1": "partial_sparse",
                "fc2": "folded",
                "fc3": "folded",
            },
        ],
        ids=["all-folded", "all-sparse", "mixed"],
    )
    def test_accel_matches_ref(self, params, batch, styles_fn):
        masks = prune.layerwise_prune(params, {n: 0.6 for n in params})
        styles = styles_fn()
        fn, _ = M.build_accel_fn(params, masks, styles)
        got = np.asarray(fn(batch))
        want = np.asarray(M.forward(params, batch, masks))
        assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_dense_accel_matches_ref(self, params, batch):
        masks = M.ones_masks(params)
        styles = {l.name: "folded" for l in M.LAYERS}
        fn, _ = M.build_accel_fn(params, masks, styles)
        assert_allclose(
            np.asarray(fn(batch)),
            np.asarray(M.forward(params, batch)),
            rtol=1e-3,
            atol=1e-3,
        )

    def test_unknown_style_rejected(self, params):
        masks = M.ones_masks(params)
        with pytest.raises(ValueError):
            M.build_accel_fn(params, masks, {"conv1": "magic"})

    def test_jittable(self, params, batch):
        masks = M.ones_masks(params)
        styles = {l.name: "folded" for l in M.LAYERS}
        fn, _ = M.build_accel_fn(params, masks, styles)
        jitted = jax.jit(fn)
        assert_allclose(
            np.asarray(jitted(batch)), np.asarray(fn(batch)), rtol=1e-5, atol=1e-5
        )


class TestLayerSpecs:
    def test_paper_arithmetic(self):
        total_w = sum(l.weight_count for l in M.LAYERS)
        total_mac = sum(l.macs_per_frame for l in M.LAYERS)
        assert total_w == 44_190
        assert total_mac == 281_640

    def test_graph_dict_consistency(self):
        g = M.graph_dict()
        mac_nodes = [n for n in g["nodes"] if n["op"] in ("conv", "fc")]
        assert len(mac_nodes) == 5
        for n, spec in zip(mac_nodes, M.LAYERS):
            assert n["weights"] == spec.weight_count
            assert n["macs_per_frame"] == spec.macs_per_frame
        pools = [n for n in g["nodes"] if n["op"] == "maxpool"]
        assert len(pools) == 2
