"""Quantisation: grid properties, STE behaviour, compression arithmetic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import quant

RNG = np.random.default_rng(11)


class TestWeightQuant:
    def test_values_on_grid(self):
        w = jnp.asarray(RNG.normal(size=(8, 16)).astype(np.float32))
        wq = np.asarray(quant.fake_quant_weight(w, 4))
        scales = np.asarray(quant.weight_scale(w, 4))
        q = wq / scales
        assert np.allclose(q, np.round(q), atol=1e-4)
        assert np.abs(q).max() <= 7 + 1e-4

    def test_idempotent(self):
        w = jnp.asarray(RNG.normal(size=(4, 30)).astype(np.float32))
        w1 = quant.fake_quant_weight(w, 4)
        w2 = quant.fake_quant_weight(w1, 4)
        assert_allclose(np.asarray(w1), np.asarray(w2), rtol=1e-5, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(bits=st.integers(2, 8), ch=st.integers(1, 12), n=st.integers(1, 64))
    def test_error_bounded(self, bits, ch, n):
        rng = np.random.default_rng(bits * 1000 + ch * 10 + n)
        w = jnp.asarray(rng.normal(size=(ch, n)).astype(np.float32))
        wq = np.asarray(quant.fake_quant_weight(w, bits))
        scale = np.asarray(quant.weight_scale(w, bits))
        assert (np.abs(np.asarray(w) - wq) <= scale * 0.5 + 1e-6).all()

    def test_zero_channel_safe(self):
        w = jnp.zeros((2, 5))
        wq = quant.fake_quant_weight(w, 4)
        assert np.isfinite(np.asarray(wq)).all()

    def test_ste_gradient_passes_through(self):
        # d/dw mean(fake_quant(w)) should be ~1/N, not 0 (STE).
        w = jnp.asarray(RNG.normal(size=(1, 8)).astype(np.float32))
        g = jax.grad(lambda x: jnp.sum(quant.fake_quant_weight(x, 4)))(w)
        assert np.abs(np.asarray(g)).max() > 0.5


class TestActQuant:
    def test_levels_and_clipping(self):
        x = jnp.asarray(np.linspace(-2, 10, 101).astype(np.float32))
        xq = np.asarray(quant.fake_quant_act(x, 4, ceil=6.0))
        assert xq.min() == 0.0
        assert xq.max() == 6.0
        scale = 6.0 / 15
        assert np.allclose(xq / scale, np.round(xq / scale), atol=1e-4)
        assert len(np.unique(xq)) <= 16

    def test_monotone(self):
        x = jnp.asarray(np.linspace(0, 6, 200).astype(np.float32))
        xq = np.asarray(quant.fake_quant_act(x, 4))
        assert (np.diff(xq) >= -1e-6).all()


class TestCompressionAccounting:
    def test_engine_free_headline(self):
        # 44,190 weights, 15.5% kept, 32->4 bit ≈ 51.6x (paper).
        dense = quant.model_bits_dense(44_190)
        nnz = int(44_190 * 0.155)
        sparse = quant.model_bits_engine_free(nnz, 4)
        assert abs(dense / sparse - 51.6) < 0.7

    def test_spec_validation(self):
        spec = quant.QuantSpec(weight_bits=4, act_bits=4)
        assert spec.weight_levels() == 7
        assert spec.act_levels() == 15
