"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

This is the core correctness signal for the compile path: the rust runtime
executes the HLO lowered from exactly these kernels, so allclose here plus
HLO round-trip tests on the rust side transitively validate the served
numbers. Hypothesis sweeps shapes (and the int8 grid) beyond the
hand-picked cases.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import conv2d as kconv
from compile.kernels import matmul as kmm
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def randf(*shape):
    return RNG.normal(size=shape).astype(np.float32)


class TestMatmul:
    @pytest.mark.parametrize(
        "b,inn,out",
        [
            (1, 8, 8),
            (3, 70, 33),
            (8, 128, 128),
            (5, 256, 120),  # fc1 shape
            (2, 120, 84),  # fc2
            (1, 84, 10),  # fc3
            (17, 150, 6),  # conv1 im2col shape
        ],
    )
    def test_matches_ref(self, b, inn, out):
        x, w = randf(b, inn), randf(inn, out)
        got = kmm.matmul(jnp.asarray(x), jnp.asarray(w))
        want = ref.matmul(jnp.asarray(x), jnp.asarray(w))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_nonsquare_tiles(self):
        x, w = randf(9, 100), randf(100, 50)
        got = kmm.matmul(jnp.asarray(x), jnp.asarray(w), bm=4, bk=32, bn=16)
        assert_allclose(np.asarray(got), x @ w, rtol=1e-4, atol=1e-4)

    def test_single_element(self):
        x, w = randf(1, 1), randf(1, 1)
        got = kmm.matmul(jnp.asarray(x), jnp.asarray(w))
        assert_allclose(np.asarray(got), x @ w, rtol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.integers(1, 12),
        inn=st.integers(1, 200),
        out=st.integers(1, 160),
    )
    def test_hypothesis_shapes(self, b, inn, out):
        rng = np.random.default_rng(b * 100003 + inn * 101 + out)
        x = rng.normal(size=(b, inn)).astype(np.float32)
        w = rng.normal(size=(inn, out)).astype(np.float32)
        got = kmm.matmul(jnp.asarray(x), jnp.asarray(w))
        assert_allclose(np.asarray(got), x @ w, rtol=2e-4, atol=2e-4)


class TestMatmulInt8:
    def test_matches_dequant(self):
        x = randf(4, 64)
        codes = RNG.integers(-7, 8, size=(64, 24)).astype(np.int8)
        scale = np.abs(randf(1, 24)) + 0.01
        got = kmm.matmul_int8(jnp.asarray(x), jnp.asarray(codes), jnp.asarray(scale))
        want = x @ (codes.astype(np.float32) * scale)
        assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(inn=st.integers(1, 130), out=st.integers(1, 100))
    def test_hypothesis_grid(self, inn, out):
        rng = np.random.default_rng(inn * 31 + out)
        x = rng.normal(size=(3, inn)).astype(np.float32)
        codes = rng.integers(-7, 8, size=(inn, out)).astype(np.int8)
        scale = (np.abs(rng.normal(size=(1, out))) + 0.01).astype(np.float32)
        got = kmm.matmul_int8(jnp.asarray(x), jnp.asarray(codes), jnp.asarray(scale))
        want = x @ (codes.astype(np.float32) * scale)
        assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)


class TestConv:
    @pytest.mark.parametrize(
        "b,h,cin,cout,k",
        [
            (1, 28, 1, 6, 5),  # conv1
            (2, 12, 6, 16, 5),  # conv2
            (1, 6, 3, 4, 3),
            (3, 5, 2, 2, 1),  # 1x1 kernel edge case
        ],
    )
    def test_matches_lax_conv(self, b, h, cin, cout, k):
        x, w = randf(b, h, h, cin), randf(k, k, cin, cout)
        got = kconv.conv2d(jnp.asarray(x), jnp.asarray(w))
        want = ref.conv2d_nhwc(jnp.asarray(x), jnp.asarray(w))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)

    def test_im2col_equals_direct(self):
        x, w = randf(2, 10, 10, 4), randf(3, 3, 4, 8)
        a = ref.conv2d_im2col(jnp.asarray(x), jnp.asarray(w))
        b_ = ref.conv2d_nhwc(jnp.asarray(x), jnp.asarray(w))
        assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4, atol=1e-5)

    def test_im2col_layout_is_khkwc(self):
        # The packing layout contract the rust weight packer relies on.
        x = np.arange(2 * 3 * 3 * 2, dtype=np.float32).reshape(2, 3, 3, 2)
        cols = np.asarray(ref.im2col(jnp.asarray(x), 2, 2))
        assert cols.shape == (2, 2, 2, 8)
        # patch element (kh=0, kw=1, c=0) of output pixel (0,0) is x[0,0,1,0]
        assert cols[0, 0, 0, 2] == x[0, 0, 1, 0]


class TestPool:
    def test_matches_ref(self):
        x = randf(3, 8, 8, 5)
        got = kconv.maxpool2x2(jnp.asarray(x))
        want = ref.maxpool2x2(jnp.asarray(x))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)

    def test_negative_values(self):
        x = -np.abs(randf(1, 4, 4, 2)) - 1.0
        got = np.asarray(kconv.maxpool2x2(jnp.asarray(x)))
        assert (got < 0).all()

    def test_odd_dims_rejected(self):
        with pytest.raises(AssertionError):
            kconv.maxpool2x2(jnp.zeros((1, 5, 4, 1)))


class TestVmemFootprint:
    def test_default_tile(self):
        fp = kmm.vmem_footprint()
        assert fp["vmem_bytes"] == (8 * 128 + 128 * 128 + 8 * 128) * 4
        assert 0 < fp["mxu_util"] <= 1.0

    def test_full_mxu_tile(self):
        fp = kmm.vmem_footprint(bm=8, bk=128, bn=128)
        assert fp["mxu_util"] == 1.0
