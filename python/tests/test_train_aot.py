"""Training loop + AOT export: a tiny QAT run must learn; the HLO text
must be parseable, input-dependent, and must NOT elide large constants
(the zero-weight regression that once broke serving — see
aot.to_hlo_text docstring)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, data, model as M, prune, train as T


@pytest.fixture(scope="module")
def tiny_run():
    x_tr, y_tr, x_te, y_te = data.make_dataset(n_train=512, n_test=128, seed=9)
    params, losses = T.train_qat(
        x_tr, y_tr, x_te, y_te, steps=60, batch=64, seed=9, log_every=0, log=lambda *_: None
    )
    return params, losses, (x_tr, y_tr, x_te, y_te)


class TestTraining:
    def test_loss_decreases(self, tiny_run):
        _, losses, _ = tiny_run
        head = np.mean(losses[:10])
        tail = np.mean(losses[-10:])
        assert tail < head * 0.7, f"loss {head} -> {tail}"

    def test_accuracy_above_chance(self, tiny_run):
        params, _, (_, _, x_te, y_te) = tiny_run
        acc = T.evaluate(params, x_te, y_te)
        assert acc > 0.5, f"accuracy {acc}"

    def test_finetune_respects_masks(self, tiny_run):
        params, _, (x_tr, y_tr, x_te, y_te) = tiny_run
        masks = prune.layerwise_prune(params, {n: 0.8 for n in params})
        ft, _ = T.finetune(
            params, masks, x_tr, y_tr, x_te, y_te, steps=20, log=lambda *_: None
        )
        for name, m in masks.items():
            inv = 1 - np.asarray(m)
            before = np.asarray(params[name]["w"]) * inv
            after = np.asarray(ft[name]["w"]) * inv
            # Gradient masking freezes pruned positions at their original
            # values (they are re-masked in the forward and at export).
            np.testing.assert_allclose(
                after, before, atol=1e-6, err_msg=f"{name} pruned weights moved"
            )
            # And surviving weights DID move (training happened).
            kept_delta = np.abs(
                (np.asarray(ft[name]["w"]) - np.asarray(params[name]["w"]))
                * np.asarray(m)
            ).max()
            assert kept_delta > 1e-5, f"{name} surviving weights frozen"

    def test_prune_profile_rows(self, tiny_run):
        params, _, (_, _, x_te, y_te) = tiny_run
        prof = T.prune_profile(
            params, x_te, y_te, sparsities=(0.5, 0.8), eval_n=128, log=lambda *_: None
        )
        assert len(prof["rows"]) == 2
        for row in prof["rows"]:
            assert 0.0 <= row["accuracy"] <= 1.0
            assert set(row["layers"]) == {l.name for l in M.LAYERS}


class TestAotExport:
    def test_hlo_text_contains_constants(self, tiny_run):
        params, _, _ = tiny_run
        masks = M.ones_masks(params)
        styles = {l.name: "folded" for l in M.LAYERS}
        text = aot.lower_accel(params, masks, styles, batch=1)
        assert "ENTRY" in text
        # THE regression test: no elided literals.
        assert "{...}" not in text, "large constants were elided from HLO"
        assert "f32[1,28,28,1]" in text

    def test_sparse_export_smaller_constants(self, tiny_run):
        params, _, _ = tiny_run
        masks = prune.layerwise_prune(params, {n: 0.9 for n in params})
        sparse_styles = {l.name: "unrolled_sparse" for l in M.LAYERS}
        dense_styles = {l.name: "folded" for l in M.LAYERS}
        dense = aot.lower_accel(params, M.ones_masks(params), dense_styles, 1)
        sparse = aot.lower_accel(params, masks, sparse_styles, 1)
        # Engine-free: pruned blocks never reach the HLO -> smaller text.
        assert len(sparse) < len(dense)

    def test_params_tensor_roundtrip(self, tiny_run):
        params, _, _ = tiny_run
        t = aot.params_to_tensors(params)
        back = aot.tensors_to_params(t)
        for name in params:
            np.testing.assert_array_equal(
                np.asarray(params[name]["w"]), np.asarray(back[name]["w"])
            )

    def test_masks_from_tensors(self):
        t = {"conv1.mask": np.ones((5, 5, 1, 6), np.uint8), "conv1.w": np.zeros(1)}
        m = aot.masks_from_tensors(t)
        assert set(m) == {"conv1"}
        assert m["conv1"].dtype == jnp.float32
