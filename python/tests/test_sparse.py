"""Engine-free sparse matmul: plan construction invariants + numeric
equivalence with the masked-dense oracle, hypothesis-swept."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels import sparse_matmul as sp

RNG = np.random.default_rng(7)


def rand_problem(inn, out, block_sparsity, rng):
    w = rng.normal(size=(inn, out)).astype(np.float32)
    mask = (rng.random((inn, out)) < 0.3).astype(np.float32)
    # Zero whole input blocks with some probability (the elision target).
    block = sp.DEFAULT_BLOCK
    for b in range(0, inn, block):
        if rng.random() < block_sparsity:
            mask[b : b + block] = 0.0
    return w, mask


class TestPlan:
    def test_elision_counts(self):
        w, mask = rand_problem(160, 12, 0.5, np.random.default_rng(0))
        plan = sp.plan_sparse_matmul(w, mask, block=16)
        assert plan["n_blocks_total"] == 10
        assert 1 <= plan["n_blocks_live"] <= 10
        assert plan["packed"].shape == (plan["n_blocks_live"] * 16, 12)
        assert plan["elision_ratio"] == 1.0 - plan["n_blocks_live"] / 10

    def test_fully_pruned_layer_keeps_one_block(self):
        w = RNG.normal(size=(32, 4)).astype(np.float32)
        mask = np.zeros((32, 4), np.float32)
        plan = sp.plan_sparse_matmul(w, mask, block=16)
        assert plan["n_blocks_live"] == 1
        x = RNG.normal(size=(3, 32)).astype(np.float32)
        y = sp.sparse_matmul(jnp.asarray(x), plan)
        assert_allclose(np.asarray(y), np.zeros((3, 4)), atol=1e-7)

    def test_non_divisible_input_padded(self):
        w, mask = rand_problem(70, 5, 0.3, np.random.default_rng(1))
        plan = sp.plan_sparse_matmul(w, mask, block=16)
        x = RNG.normal(size=(2, 70)).astype(np.float32)
        y = sp.sparse_matmul(jnp.asarray(x), plan)
        assert_allclose(np.asarray(y), x @ (w * mask), rtol=1e-4, atol=1e-4)

    def test_dense_mask_keeps_all_blocks(self):
        w = RNG.normal(size=(64, 8)).astype(np.float32)
        plan = sp.plan_sparse_matmul(w, np.ones_like(w), block=16)
        assert plan["n_blocks_live"] == 4
        assert plan["elision_ratio"] == 0.0


class TestNumerics:
    @settings(max_examples=20, deadline=None)
    @given(
        inn=st.integers(8, 200),
        out=st.integers(1, 40),
        seed=st.integers(0, 10_000),
        bs=st.floats(0.0, 0.9),
    )
    def test_matches_masked_dense(self, inn, out, seed, bs):
        rng = np.random.default_rng(seed)
        w, mask = rand_problem(inn, out, bs, rng)
        plan = sp.plan_sparse_matmul(w, mask)
        x = rng.normal(size=(4, inn)).astype(np.float32)
        got = sp.sparse_matmul(jnp.asarray(x), plan)
        want = ref.masked_matmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(mask))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_packed_oracle_agrees(self):
        rng = np.random.default_rng(3)
        w, mask = rand_problem(96, 7, 0.4, rng)
        plan = sp.plan_sparse_matmul(w, mask, block=16)
        x = rng.normal(size=(5, 96)).astype(np.float32)
        ours = np.asarray(sp.sparse_matmul(jnp.asarray(x), plan))
        oracle = ref.sparse_matmul_packed_ref(
            x, plan["packed"], plan["live"], plan["block"], plan["out_dim"]
        )
        assert_allclose(ours, oracle, rtol=1e-4, atol=1e-4)


class TestPerfModel:
    def test_pass_reduction_scales_with_elision(self):
        rng = np.random.default_rng(9)
        w, mask_lo = rand_problem(512, 16, 0.2, rng)
        _, mask_hi = rand_problem(512, 16, 0.8, np.random.default_rng(10))
        lo = sp.perf_estimate(sp.plan_sparse_matmul(w, mask_lo), batch=8)
        hi = sp.perf_estimate(sp.plan_sparse_matmul(w, mask_hi), batch=8)
        assert hi["sparse_mxu_passes"] <= lo["sparse_mxu_passes"]
        assert hi["elision_ratio"] >= lo["elision_ratio"]
        assert lo["dense_mxu_passes"] == hi["dense_mxu_passes"]

    def test_engine_free_invariant_no_mask_at_runtime(self):
        # The jitted function must not take the mask as an argument: the
        # plan bakes everything. (API-level check of the core claim.)
        w, mask = rand_problem(64, 4, 0.5, np.random.default_rng(2))
        plan = sp.plan_sparse_matmul(w, mask)
        import inspect

        sig = inspect.signature(sp.sparse_matmul)
        assert "mask" not in sig.parameters
        assert isinstance(plan["live"], list)  # static python ints
        assert all(isinstance(i, int) for i in plan["live"])
