"""Synthetic dataset determinism/learnability signals + LSTW round-trip
(the rust side re-reads these bytes; `tests/artifacts_e2e.rs` covers the
cross-language direction)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data, export as ex


class TestDataset:
    def test_shapes_and_range(self):
        x_tr, y_tr, x_te, y_te = data.make_dataset(n_train=256, n_test=64, seed=0)
        assert x_tr.shape == (256, 28, 28, 1)
        assert x_te.shape == (64, 28, 28, 1)
        assert x_tr.dtype == np.float32
        assert 0.0 <= x_tr.min() and x_tr.max() <= 1.0
        assert set(np.unique(y_tr)) == set(range(10))

    def test_deterministic_in_seed(self):
        a = data.make_dataset(64, 16, seed=5)
        b = data.make_dataset(64, 16, seed=5)
        for xa, xb in zip(a, b):
            np.testing.assert_array_equal(xa, xb)

    def test_train_test_disjoint_streams(self):
        x_tr, _, x_te, _ = data.make_dataset(64, 64, seed=1)
        assert not np.allclose(x_tr[:16], x_te[:16])

    def test_classes_are_distinguishable(self):
        # Nearest-centroid accuracy must be far above chance: the task is
        # learnable (sanity floor, way below what LeNet achieves).
        x_tr, y_tr, x_te, y_te = data.make_dataset(1024, 256, seed=2)
        cent = np.stack([x_tr[y_tr == c].mean(axis=0).ravel() for c in range(10)])
        d = ((x_te.reshape(len(x_te), -1)[:, None, :] - cent[None]) ** 2).sum(-1)
        acc = (d.argmin(1) == y_te).mean()
        assert acc > 0.5, f"nearest-centroid accuracy only {acc}"

    def test_intra_class_variation(self):
        labels = np.zeros(8, np.int32)
        rng = np.random.default_rng(0)
        imgs = data.render_batch(labels, rng)
        flat = imgs.reshape(8, -1)
        # No two renderings of the same digit identical (augmentation on).
        for i in range(8):
            for j in range(i + 1, 8):
                assert not np.allclose(flat[i], flat[j])

    def test_glyphs_complete(self):
        for d in range(10):
            g = data.glyph_array(d)
            assert g.shape == (7, 5)
            assert g.sum() > 0


class TestLstw:
    def test_roundtrip_all_dtypes(self, tmp_path):
        tensors = {
            "f": np.arange(12, dtype=np.float32).reshape(3, 4),
            "i": np.array([-5, 0, 7], np.int32),
            "b": np.array([[1, 0], [0, 1]], np.uint8),
            "c": np.array([-7, 7], np.int8),
        }
        p = tmp_path / "t.lstw"
        ex.write_lstw(p, tensors)
        back = ex.read_lstw(p)
        assert set(back) == set(tensors)
        for k in tensors:
            np.testing.assert_array_equal(back[k], tensors[k])
            assert back[k].dtype == tensors[k].dtype

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(0, 6),
        seed=st.integers(0, 1000),
    )
    def test_roundtrip_hypothesis(self, tmp_path_factory, n, seed):
        rng = np.random.default_rng(seed)
        tensors = {}
        for i in range(n):
            ndim = rng.integers(0, 4)
            shape = tuple(int(rng.integers(1, 6)) for _ in range(ndim))
            tensors[f"t{i}"] = rng.normal(size=shape).astype(np.float32)
        p = tmp_path_factory.mktemp("lstw") / "x.lstw"
        ex.write_lstw(p, tensors)
        back = ex.read_lstw(p)
        for k, v in tensors.items():
            np.testing.assert_array_equal(back[k], v)

    def test_bad_magic_rejected(self, tmp_path):
        p = tmp_path / "bad.lstw"
        p.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
        with pytest.raises(ValueError):
            ex.read_lstw(p)

    def test_unsupported_dtype_rejected(self, tmp_path):
        with pytest.raises(TypeError):
            ex.write_lstw(tmp_path / "x.lstw", {"d": np.zeros(3, np.float64)})

    def test_export_params_layout(self, tmp_path):
        from compile import model as M

        params = M.init_params(0)
        masks = M.ones_masks(params)
        p = tmp_path / "params.lstw"
        ex.export_params(p, params, masks)
        back = ex.read_lstw(p)
        assert "conv1.w" in back and "conv1.mask" in back and "fc3.b" in back
        assert back["conv1.w"].shape == (5, 5, 1, 6)
        assert back["conv1.mask"].dtype == np.uint8
