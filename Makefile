# LogicSparse reproduction — tooling entry points.
#
# `make verify` is the tier-1 gate from ROADMAP.md; CI runs exactly it.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: verify build test doc fmt fmt-check clippy bench bench-smoke bench-compare bench-baseline artifacts clean

## Tier-1 gate: release build + full test suite + doc gate + lint gate
## (rustfmt check + clippy -D warnings). Lint is a hard gate now; if a
## toolchain run still finds offline-written fmt/clippy debt, pay it
## (`make fmt`, fix findings) rather than re-softening the gate.
verify:
	$(CARGO) build --release
	$(CARGO) test -q
	$(MAKE) doc
	$(MAKE) fmt-check
	$(MAKE) clippy

## Doc gate: broken intra-doc links and missing public docs fail loudly
## (the lib carries #![warn(missing_docs)]; -D promotes rustdoc warnings).
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --quiet

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --all

fmt-check:
	$(CARGO) fmt --all -- --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

## Serving + simulator benches (engine-free parts run without artifacts).
## Each bench also writes its numbers to BENCH_<name>.json so the perf
## trajectory is machine-trackable across PRs.
bench:
	$(CARGO) bench --bench kernel_perf
	$(CARGO) bench --bench serve_perf
	$(CARGO) bench --bench sim_perf

## Fast CI smoke: small request counts, timing-ratio assertions off
## (zero-loss and accounting assertions stay on; the kernel datapath
## identity assertions — including the layer-pipelined executor's
## bit-identity and zero-dropped-frames checks — always run).
bench-smoke:
	BENCH_SMOKE=1 $(CARGO) bench --bench kernel_perf
	BENCH_SMOKE=1 $(CARGO) bench --bench serve_perf

## Diff the current BENCH_*.json files against the committed baseline.
## Reporting-only by default; STRICT=1 turns drift beyond the noise band
## (and missing baseline rows) into a nonzero exit — the ROADMAP #5
## gating step, opt-in until runner noise is characterised.
bench-compare:
	$(CARGO) run --release --quiet -- bench-compare $(if $(STRICT),--strict)

## Refresh the committed baseline from the BENCH_*.json files present
## (run `make bench` first, on a quiet machine).
bench-baseline:
	$(CARGO) run --release --quiet -- bench-compare --write-baseline

## Build the AOT artifacts (needs the python/JAX environment):
## stage 1 trains + exports, the rust DSE emits folding_config.json,
## stage 2 re-prunes and exports the proposed sparse variants.
artifacts:
	cd python/compile && $(PYTHON) aot.py --stage 1 --out ../../artifacts
	$(CARGO) run --release -- dse --artifacts artifacts
	cd python/compile && $(PYTHON) aot.py --stage 2 --out ../../artifacts

## BENCH_baseline.json is the committed snapshot — clean spares it and
## removes only the per-run outputs.
clean:
	$(CARGO) clean
	find . -maxdepth 1 -name 'BENCH_*.json' ! -name 'BENCH_baseline.json' -delete
