//! `cargo bench --bench dse_perf` — DSE wall time per strategy and its
//! scaling with model size (the paper markets the flow as *fast* design
//! space exploration from ONNX-graph estimates).

use logicsparse::config::PruneProfile;
use logicsparse::device::XCU50;
use logicsparse::dse::{self, DseOptions, Strategy};
use logicsparse::graph::builder::{convnet, lenet5};
use logicsparse::util::bench::Bencher;

fn main() {
    let g = lenet5();
    let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);
    let opts = DseOptions::default();
    let b = Bencher::default();

    for st in [Strategy::AutoFold, Strategy::AutoFoldPrune, Strategy::Proposed] {
        b.run(&format!("dse/lenet/{}", st.as_str()), || {
            dse::run(st, &g, &XCU50, &profile, &opts).unwrap().cost.total_luts
        });
    }

    for blocks in [1usize, 2, 4] {
        let big = convnet(blocks, 8, 64, 10);
        let p = PruneProfile::uniform(&big, &[0.6, 0.8], 0.9);
        let o = DseOptions { auto_fold_target_fps: 2_000.0, ..Default::default() };
        b.run(&format!("dse/convnet-{blocks}-blocks/proposed"), || {
            dse::run(Strategy::Proposed, &big, &XCU50, &p, &o)
                .unwrap()
                .cost
                .total_luts
        });
    }
}
