//! `cargo bench --bench serve_perf` — end-to-end serving performance of
//! the coordinator over the AOT artifacts: requests/second and batch
//! execute time per batch size and policy. Skips (with a notice) when
//! `make artifacts` has not been run.

use logicsparse::coordinator::{BatchPolicy, Server, ServerOptions};
use logicsparse::runtime::{ModelRuntime, IMG};
use logicsparse::util::bench::Bencher;
use logicsparse::util::lstw::Store;
use std::time::Duration;

fn main() {
    if !std::path::Path::new("artifacts/lenet_proposed_b1.hlo.txt").exists() {
        println!("serve_perf: artifacts missing — run `make artifacts` first (skipping)");
        return;
    }
    let ts = Store::read_file("artifacts/testset.lstw").unwrap();
    let images = ts.req("images").unwrap().data.as_f32().unwrap().to_vec();
    let px = IMG * IMG;
    let b = Bencher { warmup_s: 1.0, sample_s: 0.5, n_samples: 6 };

    // Raw PJRT executable rates per batch variant (no coordinator).
    let rt = ModelRuntime::load("artifacts", "proposed").unwrap();
    for batch in rt.batch_sizes() {
        let x = images[..batch * px].to_vec();
        let stats = b.run(&format!("pjrt/proposed/b{batch}"), || {
            rt.pick(batch).infer(&x).unwrap().len()
        });
        println!(
            "    -> {:.0} img/s through the executable",
            batch as f64 / stats.median()
        );
    }

    // Coordinator end-to-end under a closed-loop client.
    for (name, policy) in [
        ("low-latency", BatchPolicy::low_latency()),
        ("high-throughput", BatchPolicy::high_throughput()),
    ] {
        let server = Server::start(ServerOptions {
            policy,
            engines: 1,
            artifacts_dir: "artifacts".into(),
            tag: "proposed".into(),
        })
        .unwrap();
        let n = 256usize;
        let t0 = std::time::Instant::now();
        let mut pending = Vec::with_capacity(64);
        for j in 0..n {
            pending.push(server.submit(images[(j % 512) * px..(j % 512 + 1) * px].to_vec()).unwrap());
            if pending.len() == 64 {
                for rx in pending.drain(..) {
                    rx.recv().unwrap();
                }
            }
        }
        for rx in pending.drain(..) {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let snap = server.shutdown();
        println!(
            "coordinator/{name}: {:.0} req/s | mean batch {:.1} | p50 {:.1}ms p99 {:.1}ms",
            n as f64 / wall,
            snap.mean_batch_size,
            snap.p50_latency_s * 1e3,
            snap.p99_latency_s * 1e3
        );
        let _ = Duration::ZERO;
    }
}
