//! `cargo bench --bench serve_perf` — end-to-end serving performance of
//! the sharded execution plane.
//!
//! Part 1 runs **engine-free** (synthetic backend, no artifacts): an
//! open-loop load generator replays shared-traffic-model schedules against
//! the coordinator —
//!   * saturated traffic at 1 vs 4 engines (the engine-scaling claim:
//!     4-engine throughput must be >= 2x the 1-engine figure, with zero
//!     dropped responses across graceful shutdown);
//!   * Poisson traffic below capacity (latency percentiles + shed counts
//!     under the *same arrival process* the cycle simulator uses).
//!
//! Part 2 measures the PJRT artifact path (raw executables + coordinator)
//! and skips with a notice when `make artifacts` has not been run.

use logicsparse::coordinator::{
    loadgen, BatchPolicy, Server, ServerOptions, ShedMode,
};
use logicsparse::runtime::{ModelRuntime, SyntheticRuntime, IMG};
use logicsparse::traffic::Traffic;
use logicsparse::util::bench::Bencher;
use logicsparse::util::lstw::Store;
use std::time::Duration;

/// Deterministic synthetic image for arrival `i` (class = i % 10 under
/// the synthetic backend's stripe rule).
fn synth_image(i: u64) -> Vec<f32> {
    SyntheticRuntime::stripe_image(i as usize)
}

fn synthetic_scaling() {
    println!("== sharded plane, synthetic backend (engine-free) ==");
    let per_image = Duration::from_micros(150);
    let requests = 4000u64;
    let mut rps_by_engines = Vec::new();

    for engines in [1usize, 4] {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            engines,
            admission_capacity: 512,
            queue_depth: 16,
            ..ServerOptions::synthetic(per_image)
        })
        .unwrap();
        let traffic = Traffic::saturated(requests);
        let rep = loadgen::run_open_loop(&server, &traffic, synth_image, ShedMode::Retry);
        let snap = server.shutdown();
        println!("engines={engines}: {}", rep.render());
        println!("engines={engines}: {}", snap.render());
        assert_eq!(rep.lost, 0, "responses dropped across graceful shutdown");
        assert_eq!(rep.errors, 0, "synthetic backend must not fail");
        assert_eq!(
            rep.completed, requests,
            "saturated Retry run must complete every request"
        );
        assert_eq!(snap.completed, snap.submitted, "server lost admitted requests");
        rps_by_engines.push((engines, rep.achieved_rps));
    }

    let (_, rps1) = rps_by_engines[0];
    let (_, rps4) = rps_by_engines[1];
    println!(
        "engine scaling: 1 -> {:.0} req/s, 4 -> {:.0} req/s ({:.2}x)",
        rps1,
        rps4,
        rps4 / rps1
    );
    assert!(
        rps4 >= 2.0 * rps1,
        "engine scaling regressed: 4 engines at {rps4:.0} req/s < 2x {rps1:.0} req/s"
    );
}

fn synthetic_poisson() {
    // Open-loop Poisson at ~60% of one engine's capacity: the same
    // arrival process `sim` uses for its serving-shaped workloads.
    let per_image = Duration::from_micros(150);
    let capacity_rps = 1.0 / per_image.as_secs_f64(); // ~6.6k img/s
    let rate = 0.6 * capacity_rps;
    let server = Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
        engines: 1,
        admission_capacity: 256,
        queue_depth: 16,
        ..ServerOptions::synthetic(per_image)
    })
    .unwrap();
    let traffic = Traffic::poisson(2000, rate, 42);
    let rep = loadgen::run_open_loop(&server, &traffic, synth_image, ShedMode::Drop);
    let snap = server.shutdown();
    println!("poisson open-loop @{rate:.0} req/s: {}", rep.render());
    assert_eq!(rep.lost, 0, "responses dropped across graceful shutdown");
    assert_eq!(
        rep.completed + rep.errors,
        rep.accepted,
        "accepted requests unaccounted for"
    );
    let _ = snap;
}

fn artifact_scenarios() {
    if !std::path::Path::new("artifacts/lenet_proposed_b1.hlo.txt").exists() {
        println!("serve_perf: artifacts missing — run `make artifacts` first (skipping PJRT part)");
        return;
    }
    let ts = Store::read_file("artifacts/testset.lstw").unwrap();
    let images = ts.req("images").unwrap().data.as_f32().unwrap().to_vec();
    let px = IMG * IMG;
    let b = Bencher { warmup_s: 1.0, sample_s: 0.5, n_samples: 6 };

    // Raw PJRT executable rates per batch variant (no coordinator).
    let rt = match ModelRuntime::load("artifacts", "proposed") {
        Ok(rt) => rt,
        Err(e) => {
            println!("serve_perf: PJRT unavailable ({e}) — skipping artifact part");
            return;
        }
    };
    for batch in rt.batch_sizes() {
        let x = images[..batch * px].to_vec();
        let stats = b.run(&format!("pjrt/proposed/b{batch}"), || {
            rt.pick(batch).infer(&x).unwrap().len()
        });
        println!(
            "    -> {:.0} img/s through the executable",
            batch as f64 / stats.median()
        );
    }

    // Coordinator end-to-end under the shared traffic model (open-loop
    // bursty arrivals — directly comparable with `sim` burst workloads).
    for (name, policy) in [
        ("low-latency", BatchPolicy::low_latency()),
        ("high-throughput", BatchPolicy::high_throughput()),
    ] {
        let server = Server::start(ServerOptions {
            policy,
            engines: 1,
            ..ServerOptions::artifacts("artifacts", "proposed")
        })
        .unwrap();
        let traffic = Traffic::bursty(512, 32, 2e-3, 7);
        let n_avail = images.len() / px;
        let rep = loadgen::run_open_loop(
            &server,
            &traffic,
            |i| {
                let j = (i as usize) % n_avail;
                images[j * px..(j + 1) * px].to_vec()
            },
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        println!("coordinator/{name}: {}", rep.render());
        println!("coordinator/{name}: {}", snap.render());
        assert_eq!(rep.lost, 0);
    }
}

fn main() {
    synthetic_scaling();
    synthetic_poisson();
    artifact_scenarios();
}
