//! `cargo bench --bench serve_perf` — end-to-end serving performance of
//! the sharded execution plane.
//!
//! Part 1 runs **engine-free** (synthetic backend, no artifacts): an
//! open-loop load generator replays shared-traffic-model schedules against
//! the coordinator —
//!   * saturated traffic at 1 vs 4 engines (the engine-scaling claim:
//!     4-engine throughput must be >= 2x the 1-engine figure, with zero
//!     dropped responses across graceful shutdown);
//!   * Poisson traffic below capacity (latency percentiles + shed counts
//!     under the *same arrival process* the cycle simulator uses);
//!   * an observer-overhead pair (dark vs traced at full sample rate
//!     with a metrics registry attached): full runs assert the traced
//!     plane holds >= 0.9x the dark throughput, and the traced row
//!     carries the trace-derived stage-latency means.
//!
//! Part 2 serves **baked native kernels** (`kernel::CompiledModel`): real
//! LeNet-5-shaped integer inference with no engine at all. It compiles a
//! dense and a >=70%-sparse model from the same weights and asserts the
//! paper's point in wall-clock terms: the nnz-only schedule must beat the
//! dense loop by >= 1.2x through the full serving plane, and the compiled
//! model's compression accounting must match `experiments::headline`.
//! The auto-selection acceptance (`auto_vs_fixed`, DESIGN.md §14) then
//! serves the cost-model-driven compile against the fixed-threshold
//! compile of the same mixed-mask params: auto must never schedule more
//! MACs, must drop nothing, and (full runs) must not serve slower; every
//! row carries the per-layer chosen flavour plus predicted-vs-measured
//! cost columns.
//!
//! Part 3 is the **multi-model fleet** acceptance: a 3-tag heterogeneous
//! fleet (2 native + 1 synthetic) under a mixed Poisson arrival process
//! must sustain >= 0.8x the aggregate throughput of three isolated
//! single-model planes, with zero dropped responses (DESIGN.md §10).
//!
//! Part 4 is the **policy control plane** acceptance (DESIGN.md §11):
//! under a saturating noisy neighbour, a weighted/SLO tag must hold its
//! p99 target with zero sheds of its own — the neighbour's weighted
//! admission cap absorbs every shed — and nothing may be dropped. An
//! unweighted contrast run records how the same traffic behaves without
//! budgets (trajectory only, no assertions).
//!
//! Part 5 measures the PJRT artifact path and skips with a notice when
//! `make artifacts` has not been run.
//!
//! Every scenario's numbers are also written to `BENCH_serve.json`
//! (machine-readable perf trajectory across PRs; each row carries a
//! `model` field so fleet rows stay distinguishable). Set `BENCH_SMOKE=1` for
//! a fast CI smoke run: small request counts, and the timing-ratio
//! assertions (noisy on shared runners) are skipped while the
//! zero-loss/accounting assertions stay on.

use logicsparse::coordinator::{
    loadgen, BatchPolicy, EngineBackend, Fleet, FleetOptions, LoadReport, ModelSpec,
    Server, ServerOptions, ShedMode, StatsSnapshot,
};
use logicsparse::experiments::headline;
use logicsparse::graph::builder::lenet5;
use logicsparse::kernel::{CompiledModel, Flavour, KernelSpec};
use logicsparse::obs::ObsConfig;
use logicsparse::runtime::{ModelRuntime, SyntheticRuntime, IMG};
use logicsparse::sparsity::Mask;
use logicsparse::traffic::{Mix, Traffic};
use logicsparse::util::bench::{Bencher, BenchLog};
use logicsparse::util::lstw::Store;
use logicsparse::weights::ModelParams;
use std::sync::Arc;
use std::time::Duration;

/// Deterministic synthetic image for arrival `i` (class = i % 10 under
/// the synthetic backend's stripe rule).
fn synth_image(i: u64) -> Vec<f32> {
    SyntheticRuntime::stripe_image(i as usize)
}

/// Record one scenario row: the load report's client-side view plus the
/// plane's final snapshot (steals, shed attribution, final ring depth) —
/// so autotuning's effect on queue depths and the shed/steal trajectory
/// stay machine-readable across PRs.
fn record(log: &mut BenchLog, scenario: &str, rep: &LoadReport, snap: &StatsSnapshot) {
    log.push(scenario, &metrics(rep, snap));
}

/// Like [`record`] but labelled with the model tag (fleet scenarios).
fn record_model(
    log: &mut BenchLog,
    scenario: &str,
    model: &str,
    rep: &LoadReport,
    snap: &StatsSnapshot,
) {
    log.push_model(scenario, model, &metrics(rep, snap));
}

/// Per-layer kernel-flavour counts of a compiled model, as bench metrics
/// (`layers_<style>` = MAC layers baked with that flavour) — the same
/// attribution axis `BENCH_kernels.json` rows carry.
fn flavour_counts(model: &CompiledModel) -> Vec<(&'static str, f64)> {
    use logicsparse::folding::Style;
    [
        ("layers_folded", Style::Folded),
        ("layers_unrolled_dense", Style::UnrolledDense),
        ("layers_unrolled_sparse", Style::UnrolledSparse),
        ("layers_partial_sparse", Style::PartialSparse),
        ("layers_nm_structured", Style::NmStructured),
    ]
    .into_iter()
    .map(|(key, style)| {
        let n = model.mac_stages().filter(|m| m.style == style).count();
        (key, n as f64)
    })
    .filter(|(_, n)| *n > 0.0)
    .collect()
}

fn metrics(rep: &LoadReport, snap: &StatsSnapshot) -> Vec<(&'static str, f64)> {
    vec![
        ("rps", rep.achieved_rps),
        ("p50_ms", rep.latency_pct_s(0.5) * 1e3),
        ("p99_ms", rep.latency_pct_s(0.99) * 1e3),
        ("shed", rep.shed as f64),
        ("shed_host", snap.shed as f64),
        ("shed_budget", snap.shed_budget as f64),
        ("steals", snap.steals as f64),
        ("ring_depth", snap.ring_depth as f64),
        ("ring_full", snap.ring_full_backoffs as f64),
        ("completed", rep.completed as f64),
    ]
}

fn synthetic_scaling(log: &mut BenchLog, smoke: bool) {
    println!("== sharded plane, synthetic backend (engine-free) ==");
    let per_image = Duration::from_micros(150);
    let requests: u64 = if smoke { 200 } else { 4000 };
    let mut rps_by_engines = Vec::new();

    for engines in [1usize, 4] {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            engines,
            admission_capacity: 512,
            queue_depth: 16,
            ..ServerOptions::synthetic(per_image)
        })
        .unwrap();
        let traffic = Traffic::saturated(requests);
        let rep = loadgen::run_open_loop(&server, &traffic, synth_image, ShedMode::Retry);
        let snap = server.shutdown();
        println!("engines={engines}: {}", rep.render());
        println!("engines={engines}: {}", snap.render());
        assert_eq!(rep.lost, 0, "responses dropped across graceful shutdown");
        assert_eq!(rep.errors, 0, "synthetic backend must not fail");
        assert_eq!(
            rep.completed, requests,
            "saturated Retry run must complete every request"
        );
        assert_eq!(snap.completed, snap.submitted, "server lost admitted requests");
        record(log, &format!("synthetic_saturated_{engines}_engines"), &rep, &snap);
        rps_by_engines.push((engines, rep.achieved_rps));
    }

    let (_, rps1) = rps_by_engines[0];
    let (_, rps4) = rps_by_engines[1];
    println!(
        "engine scaling: 1 -> {:.0} req/s, 4 -> {:.0} req/s ({:.2}x)",
        rps1,
        rps4,
        rps4 / rps1
    );
    log.push("engine_scaling", &[("speedup_4_over_1", rps4 / rps1)]);
    if !smoke {
        assert!(
            rps4 >= 2.0 * rps1,
            "engine scaling regressed: 4 engines at {rps4:.0} req/s < 2x {rps1:.0} req/s"
        );
    }
}

fn synthetic_poisson(log: &mut BenchLog, smoke: bool) {
    // Open-loop Poisson at ~60% of one engine's capacity: the same
    // arrival process `sim` uses for its serving-shaped workloads.
    let per_image = Duration::from_micros(150);
    let capacity_rps = 1.0 / per_image.as_secs_f64(); // ~6.6k img/s
    let rate = 0.6 * capacity_rps;
    let requests: u64 = if smoke { 200 } else { 2000 };
    let server = Server::start(ServerOptions {
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
        engines: 1,
        admission_capacity: 256,
        queue_depth: 16,
        ..ServerOptions::synthetic(per_image)
    })
    .unwrap();
    let traffic = Traffic::poisson(requests, rate, 42);
    let rep = loadgen::run_open_loop(&server, &traffic, synth_image, ShedMode::Drop);
    let snap = server.shutdown();
    println!("poisson open-loop @{rate:.0} req/s: {}", rep.render());
    assert_eq!(rep.lost, 0, "responses dropped across graceful shutdown");
    assert_eq!(
        rep.completed + rep.errors,
        rep.accepted,
        "accepted requests unaccounted for"
    );
    record(log, "synthetic_poisson_open_loop", &rep, &snap);
}

/// Observer overhead: the same saturated synthetic workload served dark
/// and served with full-rate tracing plus an attached metrics registry.
/// Full runs assert the traced plane holds >= 0.9x the dark throughput;
/// smoke runs record the trajectory only (shared runners are noisy).
/// The traced row also carries the trace-derived stage-latency means
/// (queue/exec/total), so BenchLog rows and the trace agree on where
/// request time went.
fn traced_overhead(log: &mut BenchLog, smoke: bool) {
    use logicsparse::obs::{metrics::Registry, trace::Tracer, ObsConfig};
    println!("== observer overhead: dark vs traced serving ==");
    let per_image = Duration::from_micros(150);
    let requests: u64 = if smoke { 200 } else { 3000 };
    let run = |obs: ObsConfig| {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            engines: 2,
            admission_capacity: 512,
            queue_depth: 16,
            obs,
            ..ServerOptions::synthetic(per_image)
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &Traffic::saturated(requests),
            synth_image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        assert_eq!(rep.lost, 0, "responses dropped across graceful shutdown");
        assert_eq!(rep.completed, requests, "saturated Retry run must complete all");
        (rep, snap)
    };

    let (dark_rep, dark_snap) = run(ObsConfig::default());
    record(log, "observer_dark", &dark_rep, &dark_snap);

    let tracer = Tracer::new(1.0);
    let registry = Registry::new();
    let (traced_rep, traced_snap) = run(ObsConfig {
        tracer: Some(Arc::clone(&tracer)),
        metrics: Some(Arc::clone(&registry)),
    });
    assert_eq!(
        tracer.dropped_events(),
        0,
        "default ring capacity must hold a full-rate capture of this run"
    );
    let b = tracer.stage_breakdown();
    assert_eq!(
        b.spans as u64, requests,
        "sample rate 1.0 must assemble a complete span per request"
    );
    let mut row = metrics(&traced_rep, &traced_snap);
    row.push(("trace_spans", b.spans as f64));
    row.push(("trace_queue_us", b.queue_us));
    row.push(("trace_exec_us", b.exec_us));
    row.push(("trace_total_us", b.total_us));
    log.push("observer_traced", &row);

    let ratio = traced_rep.achieved_rps / dark_rep.achieved_rps;
    println!(
        "observer overhead: dark {:.0} req/s, traced {:.0} req/s ({ratio:.2}x) | \
         {} spans, mean queue {:.0}us exec {:.0}us total {:.0}us",
        dark_rep.achieved_rps, traced_rep.achieved_rps, b.spans, b.queue_us, b.exec_us,
        b.total_us
    );
    log.push("observer_overhead", &[("traced_over_dark_ratio", ratio)]);
    if !smoke {
        assert!(
            ratio >= 0.9,
            "tracing overhead regressed: traced plane at {ratio:.2}x of dark throughput"
        );
    }
}

/// The tentpole scenario: baked sparse kernels vs the dense native
/// baseline, both served end-to-end through the sharded plane.
fn native_kernels(log: &mut BenchLog, smoke: bool) {
    println!("== baked native kernels: sparse vs dense (engine-free) ==");
    let g = lenet5();
    let dense_params = ModelParams::synthetic(&g, 11);
    let mut sparse_params = dense_params.clone();
    sparse_params.prune_global(0.75, 0.05).unwrap();
    let spec = KernelSpec::default();
    let dense = Arc::new(CompiledModel::compile_dense(&g, &dense_params, &spec).unwrap());
    let sparse = Arc::new(CompiledModel::compile_sparse(&g, &sparse_params, &spec).unwrap());

    let sparsity = sparse.sparsity().global_sparsity();
    assert!(sparsity >= 0.70, "scenario requires >= 70% sparsity, got {sparsity}");

    // Compression accounting must match experiments::headline exactly
    // (acceptance bound: 1%) — both sides run the same formula over the
    // same ModelSparsity, so any drift is a real regression.
    let (free, csr) = headline::compression_from_sparsity(&sparse.sparsity(), spec.weights.bits);
    let own = sparse.compression();
    assert!(
        ((own - free) / free).abs() < 0.01,
        "kernel compression {own} drifted from headline accounting {free}"
    );
    println!(
        "compression: engine-free {own:.1}x (CSR-engine equivalent {csr:.1}x), \
         {} -> {} scheduled MACs/frame, {} B packed",
        dense.scheduled_macs_per_frame(),
        sparse.scheduled_macs_per_frame(),
        sparse.runtime_bytes(),
    );

    let requests: u64 = if smoke { 120 } else { 1500 };
    let mut rps = Vec::new();
    for (name, model) in [("dense", &dense), ("sparse", &sparse)] {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            engines: 2,
            admission_capacity: 512,
            queue_depth: 16,
            ..ServerOptions::native(Arc::clone(model))
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &Traffic::saturated(requests),
            synth_image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        println!("native/{name}: {}", rep.render());
        assert_eq!(rep.lost, 0, "native/{name}: responses dropped in shutdown");
        assert_eq!(rep.errors, 0, "native/{name}: kernel execution failed");
        assert_eq!(rep.completed, requests, "native/{name}: incomplete run");
        assert_eq!(
            snap.completed, snap.submitted,
            "native/{name}: admitted requests lost"
        );
        // Attribute the row the same way BENCH_kernels.json does: the
        // datapath the compiled model pinned plus how many MAC layers
        // each kernel flavour baked — so end-to-end rows and micro-bench
        // rows name the exact same configuration.
        let mut ms = metrics(&rep, &snap);
        ms.extend(flavour_counts(model));
        log.push_model(&format!("native_{name}"), model.datapath().label(), &ms);
        rps.push(rep.achieved_rps);
    }

    // Same sparse model through the third execution mode: layer-pipelined
    // stage groups (auto-sized from the core budget; on saturated hosts
    // this degenerates to one group and must still be lossless). The
    // ≥ 1.3x pipeline throughput claim lives in benches/kernel_perf.rs —
    // here we assert serving-plane integrity only.
    {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            engines: 2,
            admission_capacity: 512,
            queue_depth: 16,
            ..ServerOptions::native_pipelined(Arc::clone(&sparse), 0)
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &Traffic::saturated(requests),
            synth_image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        println!("native/sparse-pipelined: {}", rep.render());
        assert_eq!(rep.lost, 0, "pipelined: responses dropped in shutdown");
        assert_eq!(rep.errors, 0, "pipelined: kernel execution failed");
        assert_eq!(rep.completed, requests, "pipelined: incomplete run");
        assert_eq!(snap.completed, snap.submitted, "pipelined: admitted requests lost");
        let mut ms = metrics(&rep, &snap);
        ms.extend(flavour_counts(&sparse));
        log.push_model("native_sparse_pipelined", sparse.datapath().label(), &ms);
    }

    // Replicated pipeline (DESIGN.md §15): the same sparse model with
    // the costliest of 3 stage groups pinned to 2 workers. The plane
    // clamps the pin to the per-engine core budget, so on starved hosts
    // this degenerates to the unreplicated (even single-group) pipeline
    // and must still be lossless; the ≥ 1.25x replication throughput
    // claim lives in benches/kernel_perf.rs. The row carries the
    // requested shape plus the datapath label so end-to-end rows and
    // micro-bench rows name the same configuration.
    {
        let (groups_req, replicas_req) = (3usize, 2usize);
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            engines: 2,
            admission_capacity: 512,
            queue_depth: 16,
            ..ServerOptions::native_pipelined_replicated(
                Arc::clone(&sparse),
                groups_req,
                replicas_req,
            )
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &Traffic::saturated(requests),
            synth_image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        println!("native/sparse-pipelined-x{replicas_req}: {}", rep.render());
        assert_eq!(rep.lost, 0, "replicated pipeline: responses dropped in shutdown");
        assert_eq!(rep.errors, 0, "replicated pipeline: kernel execution failed");
        assert_eq!(rep.completed, requests, "replicated pipeline: incomplete run");
        assert_eq!(
            snap.completed, snap.submitted,
            "replicated pipeline: admitted requests lost"
        );
        let mut ms = metrics(&rep, &snap);
        ms.extend(flavour_counts(&sparse));
        ms.push(("stage_groups_requested", groups_req as f64));
        ms.push(("replicas_requested", replicas_req as f64));
        log.push_model(
            "native_sparse_pipelined_replicated",
            &format!("pipeline_x{replicas_req}+{}", sparse.datapath().label()),
            &ms,
        );
    }

    let speedup = rps[1] / rps[0];
    println!(
        "baked sparse vs dense native: {speedup:.2}x at {:.1}% unstructured sparsity",
        sparsity * 100.0
    );
    log.push(
        "native_sparse_vs_dense",
        &[
            ("speedup", speedup),
            ("sparsity", sparsity),
            ("compression_engine_free_x", own),
            ("compression_csr_x", csr),
        ],
    );
    if !smoke {
        assert!(
            speedup >= 1.2,
            "baked sparse backend must beat dense native by >= 1.2x at \
             {:.0}% sparsity; measured {speedup:.2}x",
            sparsity * 100.0
        );
    }
}

/// Auto-selection acceptance (DESIGN.md §14): on a LeNet-5 whose conv1
/// mask is dense and whose remaining layers are 75% pruned, the
/// cost-driven compile must never schedule more work than the
/// fixed-threshold nnz-only compile of the same params — the fixed
/// threshold bakes a pointless index stream for the dense layer, the
/// policy must fall back to the dense kernel there — and must serve at
/// least as fast through the full plane (5% noise band, full runs only).
/// Rows carry the per-layer chosen flavour and the predicted cost next
/// to the measured throughput.
fn auto_vs_fixed(log: &mut BenchLog, smoke: bool) {
    println!("== cost-driven auto-selection vs fixed-threshold compile ==");
    let g = lenet5();
    let mut params = ModelParams::synthetic(&g, 11);
    params.prune_global(0.75, 0.05).unwrap();
    let conv1 = params.layers.iter_mut().find(|l| l.name == "conv1").unwrap();
    conv1.mask = Mask::dense(conv1.w.len());
    let spec = KernelSpec::default();
    let fixed = Arc::new(CompiledModel::compile_sparse(&g, &params, &spec).unwrap());
    let (auto, choice) = CompiledModel::compile_auto(&g, &params, &spec).unwrap();
    let auto = Arc::new(auto);
    println!("{}", choice.render());

    // Structural half of the acceptance bound: holds in smoke runs too.
    assert!(
        auto.scheduled_macs_per_frame() <= fixed.scheduled_macs_per_frame(),
        "auto-selected compile schedules more MACs than the fixed threshold: \
         {} vs {}\n{}",
        auto.scheduled_macs_per_frame(),
        fixed.scheduled_macs_per_frame(),
        choice.render()
    );
    let conv1_choice = choice.get("conv1").expect("conv1 is a MAC layer");
    assert_eq!(
        conv1_choice.flavour,
        Flavour::Dense,
        "policy baked an index stream for a dense-mask layer:\n{}",
        choice.render()
    );

    let requests: u64 = if smoke { 120 } else { 1500 };
    let mut rps = Vec::new();
    for (name, model) in [("fixed", &fixed), ("auto", &auto)] {
        let server = Server::start(ServerOptions {
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) },
            engines: 2,
            admission_capacity: 512,
            queue_depth: 16,
            ..ServerOptions::native(Arc::clone(model))
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &Traffic::saturated(requests),
            synth_image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        println!("auto_vs_fixed/{name}: {}", rep.render());
        assert_eq!(rep.lost, 0, "auto_vs_fixed/{name}: responses dropped in shutdown");
        assert_eq!(rep.errors, 0, "auto_vs_fixed/{name}: kernel execution failed");
        assert_eq!(rep.completed, requests, "auto_vs_fixed/{name}: incomplete run");
        assert_eq!(
            snap.completed, snap.submitted,
            "auto_vs_fixed/{name}: admitted requests lost"
        );
        // Predicted-vs-measured on one row: the cost model's II/LUT
        // figures for the whole compile next to the served throughput.
        let mut ms = metrics(&rep, &snap);
        ms.extend(flavour_counts(model));
        ms.push(("predicted_ii_cycles", model.predicted_max_ii() as f64));
        ms.push(("predicted_luts", model.predicted_luts() as f64));
        ms.push(("scheduled_macs", model.scheduled_macs_per_frame() as f64));
        log.push_model(&format!("auto_vs_fixed_{name}"), model.datapath().label(), &ms);
        rps.push(rep.achieved_rps);
    }

    // The audit table itself, one row per layer: chosen flavour in the
    // model column, the numbers it won with as metrics.
    for l in &choice.layers {
        log.push_model(
            "auto_vs_fixed_choice",
            &format!("{}_{}", l.layer, l.flavour.as_str()),
            &[
                ("predicted_ii_cycles", l.predicted_ii as f64),
                ("predicted_luts", l.predicted_luts as f64),
                ("packed_bits", l.packed_bits as f64),
                ("feasible", if l.feasible { 1.0 } else { 0.0 }),
            ],
        );
    }

    let ratio = rps[1] / rps[0];
    println!("auto-selected vs fixed-threshold serving: {ratio:.2}x");
    log.push(
        "auto_vs_fixed",
        &[
            ("speedup", ratio),
            ("auto_scheduled_macs", auto.scheduled_macs_per_frame() as f64),
            ("fixed_scheduled_macs", fixed.scheduled_macs_per_frame() as f64),
        ],
    );
    if !smoke {
        assert!(
            ratio >= 0.95,
            "auto-selected compile served slower than the fixed-threshold \
             compile it must dominate: {:.0} vs {:.0} req/s ({ratio:.2}x)",
            rps[1],
            rps[0]
        );
    }
}

/// Multi-model acceptance scenario: a 3-tag heterogeneous fleet (2 native
/// + 1 synthetic) under a mixed Poisson arrival process must sustain
/// >= 0.8x the aggregate throughput of three isolated single-model
/// planes, with zero dropped responses — sharing one admission gate may
/// cost shed headroom under overload, but must not cost throughput when
/// every tag runs below capacity.
fn fleet_heterogeneous(log: &mut BenchLog, smoke: bool) {
    println!("== multi-model fleet: 2 native + 1 synthetic, mixed Poisson ==");
    let g = lenet5();
    let dense_params = ModelParams::synthetic(&g, 21);
    let mut sparse_params = dense_params.clone();
    sparse_params.prune_global(0.75, 0.05).unwrap();
    let spec = KernelSpec::default();
    let dense = Arc::new(CompiledModel::compile_dense(&g, &dense_params, &spec).unwrap());
    let sparse = Arc::new(CompiledModel::compile_sparse(&g, &sparse_params, &spec).unwrap());

    let dur_s = if smoke { 0.3 } else { 2.5 };
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) };
    // (tag, backend, Poisson rate in req/s, seed). Rates sit well below
    // each backend's capacity so the comparison measures coordination
    // overhead, not saturation.
    let members: Vec<(&str, EngineBackend, f64, u64)> = vec![
        (
            "lenet-dense",
            EngineBackend::Native { model: Arc::clone(&dense) },
            150.0,
            31,
        ),
        (
            "lenet-sparse",
            EngineBackend::Native { model: Arc::clone(&sparse) },
            250.0,
            32,
        ),
        (
            "synthetic",
            EngineBackend::Synthetic { per_image: Duration::from_micros(150) },
            600.0,
            33,
        ),
    ];
    let traffic_of =
        |rate: f64, seed: u64| Traffic::poisson((rate * dur_s).round() as u64, rate, seed);

    // Baseline: each model alone on its own single-model plane, replaying
    // the identical per-tag traffic.
    let mut isolated_sum = 0.0;
    for (tag, backend, rate, seed) in &members {
        let server = Server::start(ServerOptions {
            policy: policy.clone(),
            engines: 1,
            admission_capacity: 512,
            queue_depth: 16,
            backend: backend.clone(),
        })
        .unwrap();
        let rep = loadgen::run_open_loop(
            &server,
            &traffic_of(*rate, *seed),
            synth_image,
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        println!("isolated/{tag}: {}", rep.render());
        assert_eq!(rep.lost, 0, "isolated/{tag}: responses dropped");
        assert_eq!(rep.errors, 0, "isolated/{tag}: engine failures");
        assert_eq!(snap.completed, snap.submitted, "isolated/{tag}: requests lost");
        isolated_sum += rep.achieved_rps;
    }

    // The fleet: the same three models behind one shared admission gate,
    // fed the same three arrival processes merged into one schedule.
    let fleet = Fleet::start(FleetOptions {
        models: members
            .iter()
            .map(|(tag, backend, _, _)| {
                ModelSpec::new(*tag, backend.clone()).policy(policy.clone())
            })
            .collect(),
        admission_capacity: 512,
        autotune: None,
        obs: ObsConfig::default(),
    })
    .unwrap();
    let mut mix = Mix::new();
    for (tag, _, rate, seed) in &members {
        mix = mix.stream(*tag, traffic_of(*rate, *seed));
    }
    let rep = loadgen::run_open_loop_mix(&fleet, &mix, |_, i| synth_image(i), ShedMode::Retry)
        .unwrap();
    let snap = fleet.shutdown();
    println!("{}", rep.render());
    assert_eq!(rep.lost(), 0, "fleet: responses dropped across graceful shutdown");
    assert_eq!(
        rep.completed(),
        mix.events(),
        "fleet Retry run must complete every arrival"
    );
    assert_eq!(snap.completed(), snap.submitted(), "fleet: admitted requests lost");
    for (tag, r) in &rep.per_tag {
        assert_eq!(r.errors, 0, "fleet/{tag}: engine failures");
        record_model(log, &format!("fleet_{tag}"), tag, r, snap.get(tag).unwrap());
    }
    let agg = rep.aggregate_rps();
    let ratio = agg / isolated_sum;
    println!(
        "fleet aggregate {agg:.0} req/s vs isolated sum {isolated_sum:.0} req/s ({ratio:.2}x)"
    );
    log.push(
        "fleet_vs_isolated",
        &[
            ("aggregate_rps", agg),
            ("isolated_sum_rps", isolated_sum),
            ("ratio", ratio),
        ],
    );
    if !smoke {
        assert!(
            ratio >= 0.8,
            "fleet aggregate {agg:.0} req/s fell below 0.8x the isolated sum \
             {isolated_sum:.0} req/s"
        );
    }
}

/// Policy control-plane acceptance (DESIGN.md §11): one weighted/SLO
/// tag at a comfortable Poisson rate next to an unweighted neighbour
/// offered ~2.4x its capacity. With weighted admission the neighbour's
/// cap (1/9 of the shared budget) absorbs every shed while the SLO tag
/// keeps full availability and holds its p99 target; nothing is dropped.
/// A second, unweighted run of the same traffic is recorded for the
/// cross-PR trajectory (no assertions) so the policy's effect is visible
/// in `BENCH_serve.json`.
fn fleet_noisy_neighbour(log: &mut BenchLog, smoke: bool) {
    println!("== policy control plane: weighted SLO tag vs noisy neighbour ==");
    let dur_s = if smoke { 0.3 } else { 1.5 };
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500) };
    let slo_p99_ms = 20.0;
    // slo: 100us/image (~10k/s capacity) offered 2k/s. noisy: 200us/image
    // (~5k/s capacity) offered 12k/s — saturating.
    let slo_rate = 2_000.0;
    let noisy_rate = 12_000.0;
    let traffic = |rate: f64, seed: u64| {
        Traffic::poisson((rate * dur_s).round() as u64, rate, seed)
    };

    let run = |weighted: bool| {
        let slo_backend = EngineBackend::Synthetic { per_image: Duration::from_micros(100) };
        let mut slo_spec = ModelSpec::new("slo", slo_backend).policy(policy.clone());
        if weighted {
            slo_spec = slo_spec.slo(slo_p99_ms, 8.0);
        }
        let fleet = Fleet::start(FleetOptions {
            models: vec![
                slo_spec,
                ModelSpec::new(
                    "noisy",
                    EngineBackend::Synthetic { per_image: Duration::from_micros(200) },
                )
                .policy(policy.clone()),
            ],
            admission_capacity: 63,
            autotune: None,
        obs: ObsConfig::default(),
        })
        .unwrap();
        let mix = Mix::new()
            .stream("slo", traffic(slo_rate, 41))
            .stream("noisy", traffic(noisy_rate, 43));
        let rep =
            loadgen::run_open_loop_mix(&fleet, &mix, |_, i| synth_image(i), ShedMode::Drop)
                .unwrap();
        let snap = fleet.shutdown();
        (rep, snap)
    };

    // Weighted run: budgets 56/7 out of the 63-slot host gate.
    let (rep, snap) = run(true);
    let label = |w: &str| format!("noisy_neighbour_{w}");
    println!("weighted: {}", rep.render());
    println!("weighted: {}", snap.render());
    assert_eq!(rep.lost(), 0, "responses dropped across graceful shutdown");
    let slo_stats = snap.get("slo").unwrap();
    let noisy_stats = snap.get("noisy").unwrap();
    assert_eq!(slo_stats.budget_capacity, Some(56), "weights not applied");
    assert_eq!(noisy_stats.budget_capacity, Some(7), "weights not applied");
    assert!(
        noisy_stats.shed_total() > 0,
        "a 2.4x-overloaded tag behind a 7-slot cap must shed"
    );
    for (tag, r) in &rep.per_tag {
        record_model(log, &label("weighted"), tag, r, snap.get(tag).unwrap());
    }
    let slo_rep = rep.get("slo").unwrap();
    if !smoke {
        assert_eq!(
            slo_stats.shed_total(),
            0,
            "the weighted tag shed despite its reserved headroom"
        );
        assert_eq!(slo_rep.completed, slo_rep.offered, "SLO tag lost availability");
        let p99_ms = slo_rep.latency_pct_s(0.99) * 1e3;
        assert!(
            p99_ms <= slo_p99_ms,
            "weighted tag missed its SLO under a noisy neighbour: \
             p99 {p99_ms:.2}ms > {slo_p99_ms}ms"
        );
        println!(
            "slo tag held p99 {p99_ms:.2}ms <= {slo_p99_ms}ms while noisy shed {}",
            noisy_stats.shed_total()
        );
    }

    // Unweighted contrast: same traffic, FIFO-fair shared gate. Recorded
    // for the trajectory only — under saturation the noisy tag may spend
    // the whole budget and starve the SLO tag's availability.
    if !smoke {
        let (rep, snap) = run(false);
        println!("unweighted: {}", rep.render());
        for (tag, r) in &rep.per_tag {
            record_model(log, &label("unweighted"), tag, r, snap.get(tag).unwrap());
        }
    }
}

fn artifact_scenarios(log: &mut BenchLog) {
    if !std::path::Path::new("artifacts/lenet_proposed_b1.hlo.txt").exists() {
        println!("serve_perf: artifacts missing — run `make artifacts` first (skipping PJRT part)");
        return;
    }
    let ts = Store::read_file("artifacts/testset.lstw").unwrap();
    let images = ts.req("images").unwrap().data.as_f32().unwrap().to_vec();
    let px = IMG * IMG;
    let b = Bencher { warmup_s: 1.0, sample_s: 0.5, n_samples: 6 };

    // Raw PJRT executable rates per batch variant (no coordinator).
    let rt = match ModelRuntime::load("artifacts", "proposed") {
        Ok(rt) => rt,
        Err(e) => {
            println!("serve_perf: PJRT unavailable ({e}) — skipping artifact part");
            return;
        }
    };
    for batch in rt.batch_sizes() {
        let x = images[..batch * px].to_vec();
        let stats = b.run(&format!("pjrt/proposed/b{batch}"), || {
            rt.pick(batch).infer(&x).unwrap().len()
        });
        println!(
            "    -> {:.0} img/s through the executable",
            batch as f64 / stats.median()
        );
        log.push(
            &format!("pjrt_raw_b{batch}"),
            &[("img_per_s", batch as f64 / stats.median())],
        );
    }

    // Coordinator end-to-end under the shared traffic model (open-loop
    // bursty arrivals — directly comparable with `sim` burst workloads).
    for (name, policy) in [
        ("low-latency", BatchPolicy::low_latency()),
        ("high-throughput", BatchPolicy::high_throughput()),
    ] {
        let server = Server::start(ServerOptions {
            policy,
            engines: 1,
            ..ServerOptions::artifacts("artifacts", "proposed")
        })
        .unwrap();
        let traffic = Traffic::bursty(512, 32, 2e-3, 7);
        let n_avail = images.len() / px;
        let rep = loadgen::run_open_loop(
            &server,
            &traffic,
            |i| {
                let j = (i as usize) % n_avail;
                images[j * px..(j + 1) * px].to_vec()
            },
            ShedMode::Retry,
        );
        let snap = server.shutdown();
        println!("coordinator/{name}: {}", rep.render());
        println!("coordinator/{name}: {}", snap.render());
        assert_eq!(rep.lost, 0);
        record(log, &format!("pjrt_coordinator_{name}"), &rep, &snap);
    }
}

fn main() {
    // Value-sensitive: BENCH_SMOKE=0 / empty / "false" mean a full run.
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    if smoke {
        println!("serve_perf: BENCH_SMOKE set — small runs, timing assertions off");
    }
    let mut log = BenchLog::new("serve_perf");
    synthetic_scaling(&mut log, smoke);
    synthetic_poisson(&mut log, smoke);
    traced_overhead(&mut log, smoke);
    native_kernels(&mut log, smoke);
    auto_vs_fixed(&mut log, smoke);
    fleet_heterogeneous(&mut log, smoke);
    fleet_noisy_neighbour(&mut log, smoke);
    artifact_scenarios(&mut log);
    log.write("BENCH_serve.json").unwrap();
    println!("wrote BENCH_serve.json");
}
