//! Per-kernel-flavour micro-benches for the native datapaths: dense /
//! unrolled-sparse / block partial-sparse / N:M fixed-stride /
//! cost-model auto-selected, each on every compiled-in [`Datapath`]
//! plus the batch-parallel pool — the measured multiples behind the
//! vectorisation tentpole (DESIGN.md §12) and the selection-policy
//! audit (DESIGN.md §14: every flavour row carries the cost model's
//! predicted II/LUTs next to the measured rate, and per-layer rows
//! name the chosen style).
//!
//! Writes `BENCH_kernels.json` with one row per `flavour@path`, e.g.
//! `block_partial_sparse@vector`, `dense@pipeline` (the staged
//! layer-pipelined executor, DESIGN.md §13), or
//! `dense@pipeline_x2+vector` (the same pipeline with the costliest
//! group replicated, DESIGN.md §15 — the row key carries the
//! replication factor and the datapath label, and the metrics carry
//! `bottleneck_replicas`/`workers`). Identity assertions (vector,
//! pooled, pipelined, and replicated-pipelined outputs bit-identical to
//! scalar, in submit order) and the pipelines' zero-dropped-frames
//! checks run on **every** invocation, smoke included — they are cheap
//! and they are the contract. Timing assertions (vector >= 1.5x scalar
//! on the block partial-sparse flavour; pool >= 1.5x serial at batch
//! >= 8 on >= 4 cores; pipeline >= 1.3x serial on a >= 32-request dense
//! stream on >= 4 cores; replicated pipeline >= 1.25x the unreplicated
//! pipeline on >= 6 cores) only run on full runs, since smoke runs and
//! starved CI runners measure noise.
//!
//! Set `BENCH_SMOKE=1` for a fast low-fidelity pass.

use logicsparse::folding::{FoldingConfig, LayerFold, Style};
use logicsparse::graph::builder::lenet5;
use logicsparse::kernel::{
    BatchPool, CompiledModel, Datapath, Flavour, KernelSpec, StagedExecutor,
};
use logicsparse::runtime::SyntheticRuntime;
use logicsparse::util::bench::{BenchLog, Bencher};
use logicsparse::weights::ModelParams;
use std::sync::Arc;

/// The five kernel flavours on the LeNet-5 shape (the paper's model).
fn flavours() -> Vec<(&'static str, Arc<CompiledModel>)> {
    let g = lenet5();
    let spec = KernelSpec::default();

    let dense_params = ModelParams::synthetic(&g, 7);
    let dense = CompiledModel::compile_dense(&g, &dense_params, &spec).unwrap();

    let mut sparse_params = ModelParams::synthetic(&g, 7);
    sparse_params.prune_global(0.75, 0.05).unwrap();
    let sparse = CompiledModel::compile_sparse(&g, &sparse_params, &spec).unwrap();

    // Block partial-sparse: per-layer SIMD width = the largest lane
    // granularity dividing fold_in (folding enforces divisibility).
    let mut cfg = FoldingConfig::default();
    for n in g.mac_nodes() {
        let simd = [8usize, 5, 4, 2]
            .into_iter()
            .find(|s| n.fold_in() % s == 0)
            .unwrap_or(1);
        cfg.set(
            &n.name,
            LayerFold { pe: 1, simd, style: Style::PartialSparse, sparsity: 0.5 },
        );
    }
    let partial = CompiledModel::compile(&g, &sparse_params, &spec, &cfg).unwrap();

    // N:M fixed-stride: the same seed-7 weights re-masked 2:8, baked
    // as padded fixed-slot schedules (DESIGN.md §14).
    let mut nm_params = ModelParams::synthetic(&g, 7);
    nm_params.prune_nm(2, 8).unwrap();
    let nm = CompiledModel::compile_with_choice(&g, &nm_params, &spec, Flavour::Nm).unwrap();

    // Cost-model auto-selection on the unstructured 0.75 masks: the
    // policy must never schedule more work than the fixed-threshold
    // nnz-only compile of the same params.
    let (auto, choice) = CompiledModel::compile_auto(&g, &sparse_params, &spec).unwrap();
    assert!(
        auto.scheduled_macs_per_frame() <= sparse.scheduled_macs_per_frame(),
        "auto-selected compile schedules more MACs ({}) than the fixed \
         nnz-only compile ({})",
        auto.scheduled_macs_per_frame(),
        sparse.scheduled_macs_per_frame()
    );
    assert!(
        choice.layers.iter().all(|l| l.feasible),
        "auto selection left an infeasible layer on the default device:\n{}",
        choice.render()
    );

    vec![
        ("dense", Arc::new(dense)),
        ("unrolled_sparse", Arc::new(sparse)),
        ("block_partial_sparse", Arc::new(partial)),
        ("nm_structured", Arc::new(nm)),
        ("auto", Arc::new(auto)),
    ]
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n).map(SyntheticRuntime::stripe_image).collect()
}

fn main() {
    // Value-sensitive: BENCH_SMOKE=0 / empty / "false" mean a full run.
    let smoke = std::env::var("BENCH_SMOKE")
        .map(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"))
        .unwrap_or(false);
    if smoke {
        println!("kernel_perf: BENCH_SMOKE set — small runs, timing assertions off");
    }
    let bencher = if smoke { Bencher::quick() } else { Bencher::default() };
    let cores = std::thread::available_parallelism().map_or(1, |n| n.get());
    let mut log = BenchLog::new("kernel_perf");

    let imgs = images(8);
    let batch_n = if smoke { 8 } else { 16 };
    let batch: Vec<f32> = (0..batch_n)
        .flat_map(|i| imgs[i % imgs.len()].clone())
        .collect();
    let pool_workers = (cores - 1).max(1);
    let pool = BatchPool::new(pool_workers);

    for (name, model) in flavours() {
        // Identity first, always: every datapath and the pooled batch
        // path must reproduce the scalar reference bit for bit.
        let scalar_ref: Vec<Vec<f32>> = imgs
            .iter()
            .map(|img| model.forward_with(img, Datapath::Scalar).unwrap())
            .collect();
        for dp in Datapath::all() {
            for (img, want) in imgs.iter().zip(&scalar_ref) {
                assert_eq!(
                    &model.forward_with(img, dp).unwrap(),
                    want,
                    "{name}: {} datapath diverged from scalar",
                    dp.label()
                );
            }
        }
        let serial_batch = model.infer_batch(&batch, batch_n).unwrap();
        assert_eq!(
            pool.infer_batch(&model, &batch, batch_n).unwrap(),
            serial_batch,
            "{name}: pooled batch diverged from serial"
        );

        // Single-frame forward per datapath.
        let mut scalar_fps = 0.0;
        for dp in Datapath::all() {
            let mut i = 0usize;
            let m = Arc::clone(&model);
            let frames = &imgs;
            let stats = bencher.run(&format!("{name}@{}", dp.label()), move || {
                i = (i + 1) % frames.len();
                m.forward_with(&frames[i], dp).unwrap()
            });
            let fps = stats.throughput();
            if dp == Datapath::Scalar {
                scalar_fps = fps;
            }
            log.push_model(
                name,
                dp.label(),
                &[
                    ("frames_per_s", fps),
                    ("median_us", stats.median() * 1e6),
                    ("speedup_vs_scalar_x", fps / scalar_fps),
                ],
            );
        }

        // Selection-policy audit (DESIGN.md §14): the cost model's
        // predictions for the baked folds next to the measured software
        // rate, plus one row per layer whose key names the chosen style
        // — the per-layer chosen-flavour column of BENCH_kernels.json.
        log.push_model(
            name,
            "cost_model",
            &[
                ("predicted_ii_cycles", model.predicted_max_ii() as f64),
                ("predicted_luts", model.predicted_luts() as f64),
                ("scheduled_macs_per_frame", model.scheduled_macs_per_frame() as f64),
                ("measured_scalar_frames_per_s", scalar_fps),
            ],
        );
        for st in model.mac_stages() {
            log.push_model(
                name,
                &format!("layer_{}_{}", st.name, st.style.as_str()),
                &[
                    ("predicted_ii_cycles", st.predicted_ii as f64),
                    ("predicted_luts", st.predicted_luts as f64),
                    ("scheduled_macs", st.scheduled_macs() as f64),
                ],
            );
        }

        // Batch path: serial loop vs the worker pool, best datapath.
        let m = Arc::clone(&model);
        let (b, bn) = (&batch, batch_n);
        let serial_stats = bencher.run(&format!("{name}@batch_serial"), move || {
            m.infer_batch(b, bn).unwrap()
        });
        let m = Arc::clone(&model);
        let (p, b, bn) = (&pool, &batch, batch_n);
        let pool_stats = bencher.run(&format!("{name}@batch_parallel"), move || {
            p.infer_batch(&m, b, bn).unwrap()
        });
        let serial_fps = serial_stats.throughput() * bn as f64;
        let pool_fps = pool_stats.throughput() * bn as f64;
        log.push_model(
            name,
            "batch_parallel",
            &[
                ("frames_per_s", pool_fps),
                ("median_us", pool_stats.median() * 1e6),
                ("speedup_vs_serial_x", pool_fps / serial_fps),
                ("batch", bn as f64),
                ("workers", pool_workers as f64),
            ],
        );

        // Layer-pipelined path: a stream of single requests through the
        // staged executor (4 cost-balanced stage groups, one worker
        // each) vs the same stream through the serial stage walk —
        // request k's layer N overlapping request k+1's layer N−1
        // (DESIGN.md §13). Identity + zero-drop are asserted on every
        // run; the ≥ 1.3x throughput floor is acceptance-gated below.
        let exec = StagedExecutor::new(Arc::clone(&model), 4).unwrap();
        let stream_n = if smoke { 32 } else { 64 };
        let stream: Vec<f32> = (0..stream_n)
            .flat_map(|i| imgs[i % imgs.len()].clone())
            .collect();
        assert_eq!(
            exec.infer_batch(&stream, stream_n).unwrap(),
            model.infer_batch(&stream, stream_n).unwrap(),
            "{name}: pipelined stream diverged from serial"
        );
        let m = Arc::clone(&model);
        let (s, sn) = (&stream, stream_n);
        let serial_stream_stats = bencher.run(&format!("{name}@stream_serial"), move || {
            m.infer_batch(s, sn).unwrap()
        });
        let e = &exec;
        let pipe_stats = bencher.run(&format!("{name}@pipeline"), move || {
            e.infer_batch(s, sn).unwrap()
        });
        let pst = exec.stats();
        assert_eq!(pst.in_flight(), 0, "{name}: pipeline dropped frames");
        let serial_stream_fps = serial_stream_stats.throughput() * sn as f64;
        let pipe_fps = pipe_stats.throughput() * sn as f64;
        log.push_model(
            name,
            "pipeline",
            &[
                ("frames_per_s", pipe_fps),
                ("median_us", pipe_stats.median() * 1e6),
                ("speedup_vs_serial_x", pipe_fps / serial_stream_fps),
                ("stage_groups", exec.groups() as f64),
                ("stream", sn as f64),
            ],
        );

        // Replicated pipeline (DESIGN.md §15): the same 4 groups with
        // the costliest pinned to 2 workers — round-robin dispatch,
        // in-order recombination. Identity (bit-identical, submit
        // order) and zero-drop are asserted on every run; the ≥ 1.25x
        // floor over the unreplicated pipeline is acceptance-gated
        // below.
        let rexec = StagedExecutor::with_bottleneck_replication(
            Arc::clone(&model),
            4,
            2,
            logicsparse::kernel::pipeline::DEFAULT_FIFO_DEPTH,
            model.datapath(),
        )
        .unwrap();
        assert_eq!(
            rexec.infer_batch(&stream, stream_n).unwrap(),
            model.infer_batch(&stream, stream_n).unwrap(),
            "{name}: replicated pipelined stream diverged from serial"
        );
        let e = &rexec;
        let rep_label = format!(
            "pipeline_x{}+{}",
            rexec.max_replication(),
            model.datapath().label()
        );
        let rep_stats = bencher.run(&format!("{name}@{rep_label}"), move || {
            e.infer_batch(s, sn).unwrap()
        });
        assert_eq!(
            rexec.stats().in_flight(),
            0,
            "{name}: replicated pipeline dropped frames"
        );
        let rep_fps = rep_stats.throughput() * sn as f64;
        log.push_model(
            name,
            &rep_label,
            &[
                ("frames_per_s", rep_fps),
                ("median_us", rep_stats.median() * 1e6),
                ("speedup_vs_pipeline_x", rep_fps / pipe_fps),
                ("stage_groups", rexec.groups() as f64),
                ("bottleneck_replicas", rexec.max_replication() as f64),
                ("workers", rexec.worker_count() as f64),
                ("stream", sn as f64),
            ],
        );

        // Acceptance (full runs only; smoke fidelity is too low to
        // judge):
        // block partial-sparse was *designed* for lanes — the vector
        // datapath must clear 1.5x its scalar walk on LeNet-5.
        if !smoke && name == "block_partial_sparse" {
            let vec_fps = {
                let mut i = 0usize;
                let m = Arc::clone(&model);
                let frames = &imgs;
                bencher
                    .run(&format!("{name}@vector(accept)"), move || {
                        i = (i + 1) % frames.len();
                        m.forward_with(&frames[i], Datapath::Vector).unwrap()
                    })
                    .throughput()
            };
            assert!(
                vec_fps >= 1.5 * scalar_fps,
                "vectorised block partial-sparse must be >= 1.5x scalar \
                 (got {:.2}x)",
                vec_fps / scalar_fps
            );
        }
        // The pool must beat the serial loop >= 1.5x at batch >= 8 when
        // the host actually has cores to fan across.
        if !smoke && cores >= 4 {
            assert!(
                pool_fps >= 1.5 * serial_fps,
                "{name}: batch-parallel must be >= 1.5x serial on {cores} \
                 cores (got {:.2}x)",
                pool_fps / serial_fps
            );
        }
        // The staged pipeline must beat the serial single-request walk
        // >= 1.3x on a >= 32-request stream when the groups have cores
        // to live on. Dense only: its stage costs dominate any queueing
        // overhead, so the floor is robust; the sparse flavours' rows
        // are recorded for trajectory without a hard gate.
        if !smoke && cores >= 4 && name == "dense" {
            assert!(
                pipe_fps >= 1.3 * serial_stream_fps,
                "{name}: layer pipeline must be >= 1.3x serial on {cores} \
                 cores over a {sn}-request stream (got {:.2}x)",
                pipe_fps / serial_stream_fps
            );
        }
        // Replicating the costliest group must lift the II floor: the
        // 4-group LeNet-5 bottleneck (conv2) at 2 workers halves its
        // effective cost, so the replicated pipeline must clear 1.25x
        // the unreplicated one when the 5 workers all have cores to
        // live on. Dense only, same robustness argument as above; the
        // stream is >= 32 requests so the pipeline is actually full.
        if !smoke && cores >= 6 && name == "dense" {
            assert!(sn >= 32, "replication acceptance needs a saturating stream");
            assert!(
                rep_fps >= 1.25 * pipe_fps,
                "{name}: replicated pipeline (x{}) must be >= 1.25x the \
                 unreplicated pipeline on {cores} cores over a {sn}-request \
                 stream (got {:.2}x)",
                rexec.max_replication(),
                rep_fps / pipe_fps
            );
        }
    }

    log.write("BENCH_kernels.json").unwrap();
    println!("kernel_perf: wrote BENCH_kernels.json");
}
