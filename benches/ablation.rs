//! `cargo bench --bench ablation` — design-choice ablations called out in
//! DESIGN.md:
//!
//!  A. unstructured (engine-free) vs N:M structured sparsity at equal
//!     global budget — the paper's motivating comparison;
//!  B. engine-free vs CSR-style index-carrying compression;
//!  C. DSE budget sweep → Pareto frontier (Proposed vs AutoFold);
//!  D. FIFO-depth sensitivity of the measured pipeline;
//!  E. latency-trim phase on/off (what step 4 of the DSE buys).

use logicsparse::config::PruneProfile;
use logicsparse::cost;
use logicsparse::device::XCU50;
use logicsparse::dse::{self, pareto, DseOptions, Strategy};
use logicsparse::folding::{FoldingConfig, LayerFold};
use logicsparse::graph::builder::lenet5;
use logicsparse::sim::{self, Workload};
use logicsparse::sparsity::{self, nm};
use logicsparse::util::rng::Pcg32;

fn main() {
    let g = lenet5();
    let profile = PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95);

    // ---- A: unstructured vs N:M at the same layer ----
    println!("=== A. unstructured vs N:M (fc1, 30,720 weights) ===");
    let fc1 = g.node("fc1").unwrap();
    let mut rng = Pcg32::seeded(1);
    let w: Vec<f32> = (0..fc1.weights()).map(|_| rng.normal() as f32).collect();
    for (n_, m_) in [(2usize, 4usize), (1, 4), (1, 8)] {
        let mask = nm::nm_mask(&w, fc1.fold_in(), fc1.cout, n_, m_).unwrap();
        let s_nm = mask.sparsity();
        // Unstructured at the SAME sparsity: compare baked LUTs.
        let luts_nm = cost::layer_cost(
            fc1,
            &LayerFold::unrolled_sparse(fc1, s_nm),
            4,
            4,
        )
        .luts;
        let unstructured =
            sparsity::magnitude::layer_mask(&w, s_nm).unwrap();
        // Engine-free hardware cannot tell the masks apart (same nnz) —
        // the difference is ACCURACY headroom: unstructured keeps the
        // globally largest weights, N:M only the locally largest.
        let kept_mag_nm: f32 = w
            .iter()
            .zip(&mask.keep)
            .filter(|(_, &k)| k)
            .map(|(v, _)| v.abs())
            .sum();
        let kept_mag_un: f32 = w
            .iter()
            .zip(&unstructured.keep)
            .filter(|(_, &k)| k)
            .map(|(v, _)| v.abs())
            .sum();
        println!(
            "  {n_}:{m_}  sparsity {:.2}  baked {luts_nm} LUTs  kept-|w| N:M {:.1} vs unstructured {:.1} ({:+.1}%)",
            s_nm,
            kept_mag_nm,
            kept_mag_un,
            100.0 * (kept_mag_un - kept_mag_nm) / kept_mag_nm
        );
    }

    // ---- B: engine-free vs CSR compression ----
    println!("\n=== B. engine-free vs CSR-equivalent compression (whole model) ===");
    let total = g.total_weights();
    for keep in [0.5, 0.25, 0.155, 0.10] {
        let nnz = (total as f64 * keep) as usize;
        let free = sparsity::compression_ratio(total, nnz, 4);
        let csr = sparsity::compression_ratio_csr(total, nnz, 4, 16);
        println!(
            "  keep {:>5.1}%: engine-free {free:>6.1}x vs CSR {csr:>6.1}x ({:.1}x advantage)",
            keep * 100.0,
            free / csr
        );
    }

    // ---- C: budget sweep -> Pareto frontier ----
    println!("\n=== C. Pareto frontier: Proposed vs AutoFold under budget sweep ===");
    let mut prop_pts = Vec::new();
    let mut auto_pts = Vec::new();
    for i in 0..7 {
        let frac = 0.01 + 0.99 * (i as f64 / 6.0);
        let mut o = DseOptions { budget_fraction: frac, ..Default::default() };
        if let Ok(r) = dse::run(Strategy::Proposed, &g, &XCU50, &profile, &o) {
            prop_pts.push(pareto::Point {
                label: format!("prop@{frac:.2}"),
                luts: r.cost.total_luts,
                throughput_fps: r.cost.throughput_fps,
            });
        }
        o.auto_fold_target_fps = 1e9;
        if let Ok(r) = dse::run(Strategy::AutoFold, &g, &XCU50, &profile, &o) {
            auto_pts.push(pareto::Point {
                label: format!("auto@{frac:.2}"),
                luts: r.cost.total_luts,
                throughput_fps: r.cost.throughput_fps,
            });
        }
    }
    let hv_prop = pareto::hypervolume(&pareto::frontier(&prop_pts), XCU50.lut_budget(), 0.0);
    let hv_auto = pareto::hypervolume(&pareto::frontier(&auto_pts), XCU50.lut_budget(), 0.0);
    println!(
        "  hypervolume proposed {hv_prop:.3e} vs auto-fold {hv_auto:.3e} ({:.2}x — \"advances the Pareto frontier\")",
        hv_prop / hv_auto
    );

    // ---- D: FIFO depth sensitivity ----
    println!("\n=== D. FIFO depth sensitivity (measured, unrolled design) ===");
    let cfg = FoldingConfig::unrolled(&g);
    for depth in [2usize, 4, 8, 32, 128] {
        let mut p = sim::build(&g, &cfg, &XCU50, depth).unwrap();
        let rep = p.run(&Workload::Saturated { frames: 60 });
        println!(
            "  depth {depth:>3}: {:>9.0} FPS | latency {:.2} us | max occupancy {:?}",
            rep.throughput_fps,
            rep.latency_s * 1e6,
            rep.fifo_max_occupancy.iter().max().unwrap()
        );
    }

    // ---- E: latency-trim ablation (max_iterations starves phase 4) ----
    println!("\n=== E. latency-trim phase ablation ===");
    let with = dse::run(Strategy::Proposed, &g, &XCU50, &profile, &DseOptions::default()).unwrap();
    let without_opts = DseOptions { max_iterations: 10, ..Default::default() };
    let without = dse::run(Strategy::Proposed, &g, &XCU50, &profile, &without_opts).unwrap();
    println!(
        "  full DSE:     {:.2} us latency, {} LUTs, {:.0} FPS",
        with.cost.latency_s * 1e6,
        with.cost.total_luts,
        with.cost.throughput_fps
    );
    println!(
        "  capped DSE:   {:.2} us latency, {} LUTs, {:.0} FPS",
        without.cost.latency_s * 1e6,
        without.cost.total_luts,
        without.cost.throughput_fps
    );
}
