//! `cargo bench --bench fig2` — regenerates Fig. 2 (per-layer estimated
//! latency and LUT utilisation across folding/pruning strategies).

use logicsparse::config::PruneProfile;
use logicsparse::device::XCU50;
use logicsparse::experiments::fig2;
use logicsparse::graph::builder::lenet5;
use logicsparse::graph::import;
use logicsparse::util::bench::Bencher;

fn main() {
    let g = if std::path::Path::new("artifacts/graph.json").exists() {
        import::load("artifacts/graph.json").unwrap()
    } else {
        lenet5()
    };
    let profile = if std::path::Path::new("artifacts/prune_profile.json").exists() {
        PruneProfile::load("artifacts/prune_profile.json").unwrap()
    } else {
        PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95)
    };

    println!("=== Fig. 2 (estimated per-layer latency + LUTs) ===\n");
    let series = fig2::measure(&g, &XCU50, &profile).unwrap();
    println!("{}", fig2::render(&series));
    for v in fig2::shape_checks(&series) {
        println!("{v}");
    }

    println!("\n=== harness timings ===");
    Bencher::default().run("fig2/measure(4 strategies)", || {
        fig2::measure(&g, &XCU50, &profile).unwrap().len()
    });
}
