//! `cargo bench --bench sim_perf` — simulator performance (the L3 hot
//! path of the experiment harness): events/second and frames/second of
//! the DES across folding regimes and FIFO depths.
//!
//! §Perf target: the whole Table-I measurement must be interactive
//! (< 10 s); this bench tracks the underlying rates and writes them to
//! `BENCH_sim.json` so the trajectory is machine-trackable across PRs.

use logicsparse::device::XCU50;
use logicsparse::folding::FoldingConfig;
use logicsparse::graph::builder::{convnet, lenet5};
use logicsparse::sim::{self, Workload};
use logicsparse::util::bench::{BenchLog, Bencher};

fn main() {
    let g = lenet5();
    let b = Bencher::default();
    let mut log = BenchLog::new("sim_perf");
    let push = |log: &mut BenchLog, scenario: &str, frames: f64, median_s: f64| {
        log.push(
            scenario,
            &[("frames_per_s", frames / median_s), ("median_s", median_s)],
        );
    };

    for (label, cfg) in [
        ("minimal-fold", FoldingConfig::minimal(&g)),
        ("unrolled", FoldingConfig::unrolled(&g)),
    ] {
        let stats = b.run(&format!("sim/lenet/{label}/50-frames"), || {
            let mut p = sim::build(&g, &cfg, &XCU50, 8).unwrap();
            p.run(&Workload::Saturated { frames: 50 }).frames
        });
        println!("    -> {:.0} simulated frames/s", 50.0 / stats.median());
        push(&mut log, &format!("lenet_{label}"), 50.0, stats.median());
    }

    for depth in [2usize, 8, 64] {
        let cfg = FoldingConfig::unrolled(&g);
        let stats = b.run(&format!("sim/lenet/fifo-depth-{depth}/50-frames"), || {
            let mut p = sim::build(&g, &cfg, &XCU50, depth).unwrap();
            p.run(&Workload::Saturated { frames: 50 }).frames
        });
        push(&mut log, &format!("lenet_fifo_depth_{depth}"), 50.0, stats.median());
    }

    // Bigger topology: scaling check.
    let big = convnet(3, 8, 32, 10);
    let cfg = FoldingConfig::unrolled(&big);
    let stats = b.run("sim/convnet3/unrolled/20-frames", || {
        let mut p = sim::build(&big, &cfg, &XCU50, 8).unwrap();
        p.run(&Workload::Saturated { frames: 20 }).frames
    });
    push(&mut log, "convnet3_unrolled", 20.0, stats.median());

    // Poisson traffic (serving-shaped workload).
    let cfg = FoldingConfig::unrolled(&g);
    let stats = b.run("sim/lenet/poisson/100-frames", || {
        let mut p = sim::build(&g, &cfg, &XCU50, 8).unwrap();
        p.run(&Workload::Poisson { frames: 100, rate_fps: 100_000.0, seed: 1 })
            .frames
    });
    push(&mut log, "lenet_poisson", 100.0, stats.median());

    // Bursty traffic (the shared traffic model's Burst shape — the same
    // process the serving load generator replays in wall-clock time).
    let stats = b.run("sim/lenet/burst/100-frames", || {
        let mut p = sim::build(&g, &cfg, &XCU50, 8).unwrap();
        p.run(&Workload::Burst { frames: 100, burst: 16, gap_cycles: 20_000, seed: 1 })
            .frames
    });
    push(&mut log, "lenet_burst", 100.0, stats.median());

    log.write("BENCH_sim.json").unwrap();
    println!("wrote BENCH_sim.json");
}
