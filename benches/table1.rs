//! `cargo bench --bench table1` — regenerates Table I of the paper
//! (DESIGN.md §6 E1/E2) and reports the wall time of each pipeline stage.
//!
//! Uses real artifacts when present (accuracies from metrics.json),
//! otherwise the built-in graph + uniform profile.

use logicsparse::config::PruneProfile;
use logicsparse::device::XCU50;
use logicsparse::experiments::{headline, table1, Accuracies};
use logicsparse::graph::builder::lenet5;
use logicsparse::graph::import;
use logicsparse::util::bench::Bencher;

fn main() {
    let g = if std::path::Path::new("artifacts/graph.json").exists() {
        import::load("artifacts/graph.json").unwrap()
    } else {
        lenet5()
    };
    let profile = if std::path::Path::new("artifacts/prune_profile.json").exists() {
        PruneProfile::load("artifacts/prune_profile.json").unwrap()
    } else {
        PruneProfile::uniform(&g, &[0.5, 0.7, 0.8], 0.95)
    };
    let acc = Accuracies::load("artifacts").unwrap_or_default();

    println!("=== Table I (paper columns vs measured) ===\n");
    let rows = table1::measure(&g, &XCU50, &profile, &acc, 150).unwrap();
    println!("{}", table1::render(&rows));
    for v in table1::shape_checks(&rows) {
        println!("{v}");
    }
    println!();
    let h = headline::measure(&rows, "artifacts").unwrap();
    println!("{}", headline::render(&h));

    println!("=== harness timings ===");
    let b = Bencher::quick();
    b.run("table1/full-measure(5 strategies, 60 frames)", || {
        table1::measure(&g, &XCU50, &profile, &acc, 60).unwrap().len()
    });
    b.run("table1/render", || table1::render(&rows).len());
}
